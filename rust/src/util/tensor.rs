//! Dense linear-algebra substrate (BLAS-lite).
//!
//! The coordinator's hot path works on flat `f32` parameter/gradient
//! vectors; the native model backend needs small GEMMs, softmax and
//! reductions.  No external BLAS is available offline, so this module
//! implements the handful of kernels we need.  The hot entry points
//! (`dot_f32`, `axpy`, `gemm_a_bt`) ship as scalar/tiled twin pairs
//! dispatched on [`crate::util::kernel::mode`]; both twins share one
//! pinned blocked reduction order (see the `dot_f32` contract), so the
//! modes are bit-identical and the knob is wall-clock only.  The f64
//! reductions (`dot`, `norm2_sq_diff`) are deliberately strictly
//! sequential — the criterion and trace fingerprints rest on that order
//! — and have no tiled variant.

/// Row-major dense matrix view helpers live on plain `Vec<f32>`/slices —
/// a deliberate choice: everything that crosses the PJRT boundary or the
/// simulated network is a flat buffer anyway.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(rows * cols, data.len());
        Self { rows, cols, data }
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        self.data[r * self.cols + c]
    }
}

// ---------------------------------------------------------------------------
// Vector ops
// ---------------------------------------------------------------------------

/// y += a * x — dispatches on the process-wide
/// [`crate::util::kernel::mode`].  Elementwise, so the scalar and tiled
/// twins are bit-identical by construction (no cross-element reduction).
#[inline]
pub fn axpy(a: f32, x: &[f32], y: &mut [f32]) {
    match crate::util::kernel::mode() {
        crate::util::kernel::KernelMode::Scalar => axpy_scalar(a, x, y),
        crate::util::kernel::KernelMode::Tiled => axpy_tiled(a, x, y),
    }
}

/// Scalar twin of [`axpy`]: the differential-test reference.
#[inline]
pub fn axpy_scalar(a: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += a * xi;
    }
}

/// Tiled twin of [`axpy`]: 16-wide register blocks with a scalar tail.
/// Each element sees the identical `y[i] + a * x[i]` expression, so the
/// result is bit-equal to [`axpy_scalar`] for every input.
#[inline]
pub fn axpy_tiled(a: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    let n = x.len().min(y.len());
    let blocks = n / 16;
    for blk in 0..blocks {
        let o = blk * 16;
        let xs = &x[o..o + 16];
        let ys = &mut y[o..o + 16];
        for l in 0..16 {
            ys[l] += a * xs[l];
        }
    }
    for i in blocks * 16..n {
        y[i] += a * x[i];
    }
}

/// Elementwise y = x (copy preserving capacity).
#[inline]
pub fn assign(y: &mut [f32], x: &[f32]) {
    y.copy_from_slice(x);
}

#[inline]
pub fn dot(x: &[f32], y: &[f32]) -> f64 {
    debug_assert_eq!(x.len(), y.len());
    // f64 accumulation: the convergence traces subtract nearly-equal
    // numbers (loss residuals down to 1e-8), f32 accumulation is too noisy.
    let mut acc = 0.0f64;
    for (a, b) in x.iter().zip(y) {
        acc += (*a as f64) * (*b as f64);
    }
    acc
}

#[inline]
pub fn norm2_sq(x: &[f32]) -> f64 {
    dot(x, x)
}

#[inline]
pub fn norm2(x: &[f32]) -> f64 {
    norm2_sq(x).sqrt()
}

#[inline]
pub fn norm_inf(x: &[f32]) -> f32 {
    let mut m = 0.0f32;
    for &v in x {
        let a = v.abs();
        if a > m {
            m = a;
        }
    }
    m
}

/// max_i |x_i - y_i| — the quantizer radius without materializing x - y.
#[inline]
pub fn norm_inf_diff(x: &[f32], y: &[f32]) -> f32 {
    debug_assert_eq!(x.len(), y.len());
    let mut m = 0.0f32;
    for (a, b) in x.iter().zip(y) {
        let d = (a - b).abs();
        if d > m {
            m = d;
        }
    }
    m
}

/// sum_i (x_i - y_i)^2 in f64 — criterion (7a) left-hand side.
#[inline]
pub fn norm2_sq_diff(x: &[f32], y: &[f32]) -> f64 {
    debug_assert_eq!(x.len(), y.len());
    let mut acc = 0.0f64;
    for (a, b) in x.iter().zip(y) {
        let d = (*a - *b) as f64;
        acc += d * d;
    }
    acc
}

#[inline]
pub fn scale(x: &mut [f32], a: f32) {
    for v in x.iter_mut() {
        *v *= a;
    }
}

/// out = x - y (allocating).
pub fn sub(x: &[f32], y: &[f32]) -> Vec<f32> {
    x.iter().zip(y).map(|(a, b)| a - b).collect()
}

// ---------------------------------------------------------------------------
// Matmul (cache-blocked, k-panel)
// ---------------------------------------------------------------------------

const MC: usize = 64;
const KC: usize = 256;

/// C (m×n) += A (m×k, row-major) * B (k×n, row-major).
pub fn gemm_acc(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    for i0 in (0..m).step_by(MC) {
        let i1 = (i0 + MC).min(m);
        for p0 in (0..k).step_by(KC) {
            let p1 = (p0 + KC).min(k);
            for i in i0..i1 {
                let arow = &a[i * k..(i + 1) * k];
                let crow = &mut c[i * n..(i + 1) * n];
                for p in p0..p1 {
                    let aip = arow[p];
                    if aip == 0.0 {
                        continue;
                    }
                    let brow = &b[p * n..(p + 1) * n];
                    axpy(aip, brow, crow);
                }
            }
        }
    }
}

/// C = A * B (allocating convenience).
pub fn gemm(m: usize, k: usize, n: usize, a: &[f32], b: &[f32]) -> Vec<f32> {
    let mut c = vec![0.0; m * n];
    gemm_acc(m, k, n, a, b, &mut c);
    c
}

/// C (m×n) += A^T where A is (k×m), times B (k×n):  C += Aᵀ B.
pub fn gemm_at_b_acc(k: usize, m: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
    debug_assert_eq!(a.len(), k * m);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    // iterate over k rows; rank-1 update per row keeps B row-contiguous
    for p in 0..k {
        let arow = &a[p * m..(p + 1) * m];
        let brow = &b[p * n..(p + 1) * n];
        for i in 0..m {
            let aip = arow[i];
            if aip == 0.0 {
                continue;
            }
            axpy(aip, brow, &mut c[i * n..(i + 1) * n]);
        }
    }
}

/// C (m×n) = A (m×k) * B^T where B is (n×k):  C = A Bᵀ — dispatches on
/// the process-wide [`crate::util::kernel::mode`].
///
/// Both twins compute every output element with the pinned
/// [`dot_f32`] reduction order (see its accumulation-order contract), so
/// the tiling only reorders WHICH elements are computed when — never the
/// additions inside one element — and the modes stay bit-identical.
pub fn gemm_a_bt(m: usize, k: usize, n: usize, a: &[f32], b: &[f32]) -> Vec<f32> {
    match crate::util::kernel::mode() {
        crate::util::kernel::KernelMode::Scalar => gemm_a_bt_scalar(m, k, n, a, b),
        crate::util::kernel::KernelMode::Tiled => gemm_a_bt_tiled(m, k, n, a, b),
    }
}

/// Scalar twin of [`gemm_a_bt`]: row-at-a-time, every element one
/// [`dot_f32_scalar`] call.  The differential-test reference.
pub fn gemm_a_bt_scalar(m: usize, k: usize, n: usize, a: &[f32], b: &[f32]) -> Vec<f32> {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    let mut c = vec![0.0f32; m * n];
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut c[i * n..(i + 1) * n];
        for (j, cj) in crow.iter_mut().enumerate() {
            let brow = &b[j * k..(j + 1) * k];
            *cj = dot_f32_scalar(arow, brow);
        }
    }
    c
}

/// i-block size for [`gemm_a_bt_tiled`]: A-rows kept hot while a B tile
/// is resident.
const ABT_MB: usize = 32;
/// j-block size for [`gemm_a_bt_tiled`]: B-rows (length k each) reused
/// across the whole i-block from L1/L2 instead of being re-streamed per
/// output row.
const ABT_NB: usize = 8;

/// Tiled twin of [`gemm_a_bt`]: (MB × NB) register/cache blocking over
/// the output.  Each element is still one [`dot_f32_tiled`] over the
/// full k extent — the pinned reduction order — so results are bit-equal
/// to [`gemm_a_bt_scalar`]; only the traversal order of output elements
/// (and therefore cache behaviour) changes.
pub fn gemm_a_bt_tiled(m: usize, k: usize, n: usize, a: &[f32], b: &[f32]) -> Vec<f32> {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    let mut c = vec![0.0f32; m * n];
    for j0 in (0..n).step_by(ABT_NB) {
        let j1 = (j0 + ABT_NB).min(n);
        for i0 in (0..m).step_by(ABT_MB) {
            let i1 = (i0 + ABT_MB).min(m);
            for i in i0..i1 {
                let arow = &a[i * k..(i + 1) * k];
                let crow = &mut c[i * n..(i + 1) * n];
                for j in j0..j1 {
                    let brow = &b[j * k..(j + 1) * k];
                    crow[j] = dot_f32_tiled(arow, brow);
                }
            }
        }
    }
    c
}

/// f32-accumulated dot for inner GEMM loops (speed over the f64 `dot`)
/// — dispatches on the process-wide [`crate::util::kernel::mode`].
///
/// # Accumulation-order contract
///
/// Both twins implement one pinned blocked reduction order, and every
/// caller (GEMMs, the logreg/mlp per-row logits) may rely on it:
///
/// 1. lane `l ∈ [0, 16)` accumulates `Σ_c x[16c + l] · y[16c + l]` over
///    the full 16-element chunks, additions in ascending chunk order;
/// 2. the 16 lane partials are summed in lane-index order
///    (`acc.iter().sum()`);
/// 3. the `< 16` tail elements are added sequentially onto that sum.
///
/// The 16-lane shape is sized so the compiler CAN map step 1 onto one
/// AVX-512 zmm (or two AVX2 ymm) FMA chains — that is an optimization
/// hint, not an asserted guarantee; what IS guaranteed (and pinned by
/// `rust/tests/kernel_equivalence.rs` plus the shape-coverage tests
/// below) is the order above, which makes `scalar` and `tiled` — and
/// therefore whole training traces — bit-identical.
#[inline]
pub fn dot_f32(x: &[f32], y: &[f32]) -> f32 {
    match crate::util::kernel::mode() {
        crate::util::kernel::KernelMode::Scalar => dot_f32_scalar(x, y),
        crate::util::kernel::KernelMode::Tiled => dot_f32_tiled(x, y),
    }
}

/// Scalar twin of [`dot_f32`]: the plainest expression of the
/// accumulation-order contract, and the differential-test reference.
#[inline]
pub fn dot_f32_scalar(x: &[f32], y: &[f32]) -> f32 {
    let n = x.len().min(y.len());
    let (xc, yc) = (&x[..n], &y[..n]);
    let mut acc = [0.0f32; 16];
    let chunks = n / 16;
    for c in 0..chunks {
        let o = c * 16;
        for l in 0..16 {
            acc[l] += xc[o + l] * yc[o + l];
        }
    }
    let mut s = acc.iter().sum::<f32>();
    for i in chunks * 16..n {
        s += xc[i] * yc[i];
    }
    s
}

/// Tiled twin of [`dot_f32`]: 4×16 register blocks.  Per lane the four
/// products of a block are independent (ILP across FMA chains) but are
/// added onto the accumulator in ascending chunk order — exactly the
/// order the scalar twin uses — so the result is bit-equal for every
/// input.  Leftover full chunks and the scalar tail follow the contract
/// steps 1–3 unchanged.
#[inline]
pub fn dot_f32_tiled(x: &[f32], y: &[f32]) -> f32 {
    let n = x.len().min(y.len());
    let (xc, yc) = (&x[..n], &y[..n]);
    let mut acc = [0.0f32; 16];
    let chunks = n / 16;
    let quads = chunks / 4;
    for q in 0..quads {
        let o = q * 64;
        for l in 0..16 {
            let p0 = xc[o + l] * yc[o + l];
            let p1 = xc[o + 16 + l] * yc[o + 16 + l];
            let p2 = xc[o + 32 + l] * yc[o + 32 + l];
            let p3 = xc[o + 48 + l] * yc[o + 48 + l];
            // chunk-ordered adds: (((acc + p0) + p1) + p2) + p3
            acc[l] = (((acc[l] + p0) + p1) + p2) + p3;
        }
    }
    for c in quads * 4..chunks {
        let o = c * 16;
        for l in 0..16 {
            acc[l] += xc[o + l] * yc[o + l];
        }
    }
    let mut s = acc.iter().sum::<f32>();
    for i in chunks * 16..n {
        s += xc[i] * yc[i];
    }
    s
}

// ---------------------------------------------------------------------------
// NN nonlinearities
// ---------------------------------------------------------------------------

/// Row-wise in-place softmax with max-subtraction stability.
pub fn softmax_rows(x: &mut [f32], rows: usize, cols: usize) {
    debug_assert_eq!(x.len(), rows * cols);
    for r in 0..rows {
        let row = &mut x[r * cols..(r + 1) * cols];
        let mx = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
        let mut sum = 0.0f32;
        for v in row.iter_mut() {
            *v = (*v - mx).exp();
            sum += *v;
        }
        let inv = 1.0 / sum;
        for v in row.iter_mut() {
            *v *= inv;
        }
    }
}

/// Row-wise log-sum-exp (for cross-entropy without materializing softmax).
pub fn logsumexp_row(row: &[f32]) -> f32 {
    let mx = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
    let s: f32 = row.iter().map(|&v| (v - mx).exp()).sum();
    mx + s.ln()
}

#[inline]
pub fn relu(x: &mut [f32]) {
    for v in x.iter_mut() {
        if *v < 0.0 {
            *v = 0.0;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn naive_gemm(m: usize, k: usize, n: usize, a: &[f32], b: &[f32]) -> Vec<f32> {
        let mut c = vec![0.0f32; m * n];
        for i in 0..m {
            for p in 0..k {
                for j in 0..n {
                    c[i * n + j] += a[i * k + p] * b[p * n + j];
                }
            }
        }
        c
    }

    #[test]
    fn gemm_matches_naive() {
        let mut rng = crate::util::rng::Rng::new(1);
        for &(m, k, n) in &[(1, 1, 1), (3, 4, 5), (17, 33, 9), (64, 128, 10)] {
            let a: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
            let b: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
            let c1 = gemm(m, k, n, &a, &b);
            let c2 = naive_gemm(m, k, n, &a, &b);
            for (x, y) in c1.iter().zip(&c2) {
                assert!((x - y).abs() < 1e-3 * (1.0 + y.abs()), "{x} vs {y}");
            }
        }
    }

    #[test]
    fn gemm_at_b_matches_naive_transpose() {
        let mut rng = crate::util::rng::Rng::new(2);
        let (k, m, n) = (13, 7, 5);
        let a: Vec<f32> = (0..k * m).map(|_| rng.normal() as f32).collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
        let mut c = vec![0.0f32; m * n];
        gemm_at_b_acc(k, m, n, &a, &b, &mut c);
        // naive: at[i][j] = sum_p a[p][i] * b[p][j]
        for i in 0..m {
            for j in 0..n {
                let mut s = 0.0f32;
                for p in 0..k {
                    s += a[p * m + i] * b[p * n + j];
                }
                assert!((c[i * n + j] - s).abs() < 1e-4, "{} vs {s}", c[i * n + j]);
            }
        }
    }

    #[test]
    fn gemm_a_bt_matches() {
        let mut rng = crate::util::rng::Rng::new(3);
        let (m, k, n) = (6, 11, 4);
        let a: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
        let b: Vec<f32> = (0..n * k).map(|_| rng.normal() as f32).collect();
        let c = gemm_a_bt(m, k, n, &a, &b);
        for i in 0..m {
            for j in 0..n {
                let mut s = 0.0f32;
                for p in 0..k {
                    s += a[i * k + p] * b[j * k + p];
                }
                assert!((c[i * n + j] - s).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn softmax_rows_are_distributions() {
        let mut x = vec![1.0, 2.0, 3.0, -1.0, 0.0, 1.0];
        softmax_rows(&mut x, 2, 3);
        for r in 0..2 {
            let s: f32 = x[r * 3..(r + 1) * 3].iter().sum();
            assert!((s - 1.0).abs() < 1e-6);
        }
        assert!(x[2] > x[1] && x[1] > x[0]);
    }

    #[test]
    fn softmax_stable_at_large_logits() {
        let mut x = vec![1000.0, 1001.0];
        softmax_rows(&mut x, 1, 2);
        assert!(x.iter().all(|v| v.is_finite()));
        assert!((x[0] + x[1] - 1.0).abs() < 1e-6);
    }

    #[test]
    fn logsumexp_matches_direct_small() {
        let row = [0.1f32, -0.4, 0.7];
        let direct = row.iter().map(|v| v.exp()).sum::<f32>().ln();
        assert!((logsumexp_row(&row) - direct).abs() < 1e-6);
    }

    #[test]
    fn norms_and_axpy() {
        let x = vec![3.0f32, -4.0];
        assert!((norm2(&x) - 5.0).abs() < 1e-9);
        assert_eq!(norm_inf(&x), 4.0);
        let y = vec![1.0f32, 1.0];
        assert_eq!(norm_inf_diff(&x, &y), 5.0);
        assert!((norm2_sq_diff(&x, &y) - (4.0 + 25.0)).abs() < 1e-9);
        let mut z = vec![1.0f32, 2.0];
        axpy(2.0, &x, &mut z);
        assert_eq!(z, vec![7.0, -6.0]);
    }

    #[test]
    fn dot_f32_matches_dot() {
        let mut rng = crate::util::rng::Rng::new(5);
        let x: Vec<f32> = (0..1031).map(|_| rng.normal() as f32).collect();
        let y: Vec<f32> = (0..1031).map(|_| rng.normal() as f32).collect();
        let d1 = dot_f32(&x, &y) as f64;
        let d2 = dot(&x, &y);
        assert!((d1 - d2).abs() < 1e-2 * (1.0 + d2.abs()));
    }

    /// Shape sweep for the accumulation-order contract: every remainder
    /// regime of the 16-lane blocked order (empty, sub-chunk n < 16, one
    /// chunk, 16k ± 1 around the chunk AND the 64-wide tiled-quad
    /// boundaries) must agree bit-for-bit between the scalar and tiled
    /// twins, and track the f64 reference.
    #[test]
    fn dot_f32_twins_bit_equal_across_remainder_shapes() {
        let mut rng = crate::util::rng::Rng::new(6);
        for &n in &[
            0usize, 1, 2, 7, 15, 16, 17, 31, 32, 33, 47, 48, 63, 64, 65, 79, 80, 127,
            128, 129, 255, 256, 257, 1023, 1024, 1025,
        ] {
            let x: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
            let y: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
            let ds = dot_f32_scalar(&x, &y);
            let dt = dot_f32_tiled(&x, &y);
            assert_eq!(
                ds.to_bits(),
                dt.to_bits(),
                "scalar/tiled dot drift at n={n}: {ds} vs {dt}"
            );
            let dref = dot(&x, &y);
            assert!(
                (ds as f64 - dref).abs() < 1e-3 * (1.0 + dref.abs()),
                "n={n}: {ds} vs f64 {dref}"
            );
        }
    }

    #[test]
    fn dot_f32_twins_handle_mismatched_lengths() {
        // both twins clamp to min(len) — the GEMM callers rely on it
        let x: Vec<f32> = (0..70).map(|i| i as f32 * 0.25).collect();
        let y: Vec<f32> = (0..65).map(|i| 1.0 - i as f32 * 0.125).collect();
        assert_eq!(
            dot_f32_scalar(&x, &y).to_bits(),
            dot_f32_tiled(&x, &y).to_bits()
        );
        assert_eq!(
            dot_f32_scalar(&y, &x).to_bits(),
            dot_f32_scalar(&x, &y).to_bits()
        );
    }

    #[test]
    fn axpy_twins_bit_equal() {
        let mut rng = crate::util::rng::Rng::new(7);
        for &n in &[0usize, 1, 15, 16, 17, 64, 100, 1025] {
            let x: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
            let y0: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
            let a = rng.normal() as f32;
            let mut ys = y0.clone();
            let mut yt = y0.clone();
            axpy_scalar(a, &x, &mut ys);
            axpy_tiled(a, &x, &mut yt);
            assert_eq!(ys, yt, "axpy twins drift at n={n}");
        }
    }

    #[test]
    fn gemm_a_bt_twins_bit_equal_over_adversarial_shapes() {
        // shapes straddling the (MB, NB) = (32, 8) tile: exact multiples,
        // tile ± 1, single row/col, and empty extents
        let mut rng = crate::util::rng::Rng::new(8);
        for &(m, k, n) in &[
            (1usize, 1usize, 1usize),
            (1, 17, 9),
            (31, 16, 7),
            (32, 33, 8),
            (33, 64, 9),
            (64, 65, 16),
            (5, 0, 3),
            (0, 4, 2),
            (3, 4, 0),
        ] {
            let a: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
            let b: Vec<f32> = (0..n * k).map(|_| rng.normal() as f32).collect();
            let cs = gemm_a_bt_scalar(m, k, n, &a, &b);
            let ct = gemm_a_bt_tiled(m, k, n, &a, &b);
            assert_eq!(cs, ct, "gemm_a_bt twins drift at ({m},{k},{n})");
        }
    }

    #[test]
    fn mat_row_access() {
        let m = Mat::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(m.row(1), &[4., 5., 6.]);
        assert_eq!(m.at(0, 2), 3.0);
    }
}
