//! Dense linear-algebra substrate (BLAS-lite).
//!
//! The coordinator's hot path works on flat `f32` parameter/gradient
//! vectors; the native model backend needs small GEMMs, softmax and
//! reductions.  No external BLAS is available offline, so this module
//! implements the handful of kernels we need, with cache-blocked matmul
//! and (on x86_64) an 8-wide manually unrolled inner loop the compiler
//! auto-vectorizes.

/// Row-major dense matrix view helpers live on plain `Vec<f32>`/slices —
/// a deliberate choice: everything that crosses the PJRT boundary or the
/// simulated network is a flat buffer anyway.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(rows * cols, data.len());
        Self { rows, cols, data }
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        self.data[r * self.cols + c]
    }
}

// ---------------------------------------------------------------------------
// Vector ops
// ---------------------------------------------------------------------------

/// y += a * x
#[inline]
pub fn axpy(a: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += a * xi;
    }
}

/// Elementwise y = x (copy preserving capacity).
#[inline]
pub fn assign(y: &mut [f32], x: &[f32]) {
    y.copy_from_slice(x);
}

#[inline]
pub fn dot(x: &[f32], y: &[f32]) -> f64 {
    debug_assert_eq!(x.len(), y.len());
    // f64 accumulation: the convergence traces subtract nearly-equal
    // numbers (loss residuals down to 1e-8), f32 accumulation is too noisy.
    let mut acc = 0.0f64;
    for (a, b) in x.iter().zip(y) {
        acc += (*a as f64) * (*b as f64);
    }
    acc
}

#[inline]
pub fn norm2_sq(x: &[f32]) -> f64 {
    dot(x, x)
}

#[inline]
pub fn norm2(x: &[f32]) -> f64 {
    norm2_sq(x).sqrt()
}

#[inline]
pub fn norm_inf(x: &[f32]) -> f32 {
    let mut m = 0.0f32;
    for &v in x {
        let a = v.abs();
        if a > m {
            m = a;
        }
    }
    m
}

/// max_i |x_i - y_i| — the quantizer radius without materializing x - y.
#[inline]
pub fn norm_inf_diff(x: &[f32], y: &[f32]) -> f32 {
    debug_assert_eq!(x.len(), y.len());
    let mut m = 0.0f32;
    for (a, b) in x.iter().zip(y) {
        let d = (a - b).abs();
        if d > m {
            m = d;
        }
    }
    m
}

/// sum_i (x_i - y_i)^2 in f64 — criterion (7a) left-hand side.
#[inline]
pub fn norm2_sq_diff(x: &[f32], y: &[f32]) -> f64 {
    debug_assert_eq!(x.len(), y.len());
    let mut acc = 0.0f64;
    for (a, b) in x.iter().zip(y) {
        let d = (*a - *b) as f64;
        acc += d * d;
    }
    acc
}

#[inline]
pub fn scale(x: &mut [f32], a: f32) {
    for v in x.iter_mut() {
        *v *= a;
    }
}

/// out = x - y (allocating).
pub fn sub(x: &[f32], y: &[f32]) -> Vec<f32> {
    x.iter().zip(y).map(|(a, b)| a - b).collect()
}

// ---------------------------------------------------------------------------
// Matmul (cache-blocked, k-panel)
// ---------------------------------------------------------------------------

const MC: usize = 64;
const KC: usize = 256;

/// C (m×n) += A (m×k, row-major) * B (k×n, row-major).
pub fn gemm_acc(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    for i0 in (0..m).step_by(MC) {
        let i1 = (i0 + MC).min(m);
        for p0 in (0..k).step_by(KC) {
            let p1 = (p0 + KC).min(k);
            for i in i0..i1 {
                let arow = &a[i * k..(i + 1) * k];
                let crow = &mut c[i * n..(i + 1) * n];
                for p in p0..p1 {
                    let aip = arow[p];
                    if aip == 0.0 {
                        continue;
                    }
                    let brow = &b[p * n..(p + 1) * n];
                    axpy(aip, brow, crow);
                }
            }
        }
    }
}

/// C = A * B (allocating convenience).
pub fn gemm(m: usize, k: usize, n: usize, a: &[f32], b: &[f32]) -> Vec<f32> {
    let mut c = vec![0.0; m * n];
    gemm_acc(m, k, n, a, b, &mut c);
    c
}

/// C (m×n) += A^T where A is (k×m), times B (k×n):  C += Aᵀ B.
pub fn gemm_at_b_acc(k: usize, m: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
    debug_assert_eq!(a.len(), k * m);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    // iterate over k rows; rank-1 update per row keeps B row-contiguous
    for p in 0..k {
        let arow = &a[p * m..(p + 1) * m];
        let brow = &b[p * n..(p + 1) * n];
        for i in 0..m {
            let aip = arow[i];
            if aip == 0.0 {
                continue;
            }
            axpy(aip, brow, &mut c[i * n..(i + 1) * n]);
        }
    }
}

/// C (m×n) = A (m×k) * B^T where B is (n×k):  C = A Bᵀ.
pub fn gemm_a_bt(m: usize, k: usize, n: usize, a: &[f32], b: &[f32]) -> Vec<f32> {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    let mut c = vec![0.0f32; m * n];
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut c[i * n..(i + 1) * n];
        for (j, cj) in crow.iter_mut().enumerate() {
            let brow = &b[j * k..(j + 1) * k];
            *cj = dot_f32(arow, brow);
        }
    }
    c
}

/// f32-accumulated dot for inner GEMM loops (speed over the f64 `dot`).
/// 16-lane accumulator: fills one AVX-512 zmm (or two AVX2 ymm) FMA
/// chains — §Perf iteration 5.
#[inline]
pub fn dot_f32(x: &[f32], y: &[f32]) -> f32 {
    let n = x.len().min(y.len());
    let (xc, yc) = (&x[..n], &y[..n]);
    let mut acc = [0.0f32; 16];
    let chunks = n / 16;
    for c in 0..chunks {
        let o = c * 16;
        for l in 0..16 {
            acc[l] += xc[o + l] * yc[o + l];
        }
    }
    let mut s = acc.iter().sum::<f32>();
    for i in chunks * 16..n {
        s += xc[i] * yc[i];
    }
    s
}

// ---------------------------------------------------------------------------
// NN nonlinearities
// ---------------------------------------------------------------------------

/// Row-wise in-place softmax with max-subtraction stability.
pub fn softmax_rows(x: &mut [f32], rows: usize, cols: usize) {
    debug_assert_eq!(x.len(), rows * cols);
    for r in 0..rows {
        let row = &mut x[r * cols..(r + 1) * cols];
        let mx = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
        let mut sum = 0.0f32;
        for v in row.iter_mut() {
            *v = (*v - mx).exp();
            sum += *v;
        }
        let inv = 1.0 / sum;
        for v in row.iter_mut() {
            *v *= inv;
        }
    }
}

/// Row-wise log-sum-exp (for cross-entropy without materializing softmax).
pub fn logsumexp_row(row: &[f32]) -> f32 {
    let mx = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
    let s: f32 = row.iter().map(|&v| (v - mx).exp()).sum();
    mx + s.ln()
}

#[inline]
pub fn relu(x: &mut [f32]) {
    for v in x.iter_mut() {
        if *v < 0.0 {
            *v = 0.0;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn naive_gemm(m: usize, k: usize, n: usize, a: &[f32], b: &[f32]) -> Vec<f32> {
        let mut c = vec![0.0f32; m * n];
        for i in 0..m {
            for p in 0..k {
                for j in 0..n {
                    c[i * n + j] += a[i * k + p] * b[p * n + j];
                }
            }
        }
        c
    }

    #[test]
    fn gemm_matches_naive() {
        let mut rng = crate::util::rng::Rng::new(1);
        for &(m, k, n) in &[(1, 1, 1), (3, 4, 5), (17, 33, 9), (64, 128, 10)] {
            let a: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
            let b: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
            let c1 = gemm(m, k, n, &a, &b);
            let c2 = naive_gemm(m, k, n, &a, &b);
            for (x, y) in c1.iter().zip(&c2) {
                assert!((x - y).abs() < 1e-3 * (1.0 + y.abs()), "{x} vs {y}");
            }
        }
    }

    #[test]
    fn gemm_at_b_matches_naive_transpose() {
        let mut rng = crate::util::rng::Rng::new(2);
        let (k, m, n) = (13, 7, 5);
        let a: Vec<f32> = (0..k * m).map(|_| rng.normal() as f32).collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
        let mut c = vec![0.0f32; m * n];
        gemm_at_b_acc(k, m, n, &a, &b, &mut c);
        // naive: at[i][j] = sum_p a[p][i] * b[p][j]
        for i in 0..m {
            for j in 0..n {
                let mut s = 0.0f32;
                for p in 0..k {
                    s += a[p * m + i] * b[p * n + j];
                }
                assert!((c[i * n + j] - s).abs() < 1e-4, "{} vs {s}", c[i * n + j]);
            }
        }
    }

    #[test]
    fn gemm_a_bt_matches() {
        let mut rng = crate::util::rng::Rng::new(3);
        let (m, k, n) = (6, 11, 4);
        let a: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
        let b: Vec<f32> = (0..n * k).map(|_| rng.normal() as f32).collect();
        let c = gemm_a_bt(m, k, n, &a, &b);
        for i in 0..m {
            for j in 0..n {
                let mut s = 0.0f32;
                for p in 0..k {
                    s += a[i * k + p] * b[j * k + p];
                }
                assert!((c[i * n + j] - s).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn softmax_rows_are_distributions() {
        let mut x = vec![1.0, 2.0, 3.0, -1.0, 0.0, 1.0];
        softmax_rows(&mut x, 2, 3);
        for r in 0..2 {
            let s: f32 = x[r * 3..(r + 1) * 3].iter().sum();
            assert!((s - 1.0).abs() < 1e-6);
        }
        assert!(x[2] > x[1] && x[1] > x[0]);
    }

    #[test]
    fn softmax_stable_at_large_logits() {
        let mut x = vec![1000.0, 1001.0];
        softmax_rows(&mut x, 1, 2);
        assert!(x.iter().all(|v| v.is_finite()));
        assert!((x[0] + x[1] - 1.0).abs() < 1e-6);
    }

    #[test]
    fn logsumexp_matches_direct_small() {
        let row = [0.1f32, -0.4, 0.7];
        let direct = row.iter().map(|v| v.exp()).sum::<f32>().ln();
        assert!((logsumexp_row(&row) - direct).abs() < 1e-6);
    }

    #[test]
    fn norms_and_axpy() {
        let x = vec![3.0f32, -4.0];
        assert!((norm2(&x) - 5.0).abs() < 1e-9);
        assert_eq!(norm_inf(&x), 4.0);
        let y = vec![1.0f32, 1.0];
        assert_eq!(norm_inf_diff(&x, &y), 5.0);
        assert!((norm2_sq_diff(&x, &y) - (4.0 + 25.0)).abs() < 1e-9);
        let mut z = vec![1.0f32, 2.0];
        axpy(2.0, &x, &mut z);
        assert_eq!(z, vec![7.0, -6.0]);
    }

    #[test]
    fn dot_f32_matches_dot() {
        let mut rng = crate::util::rng::Rng::new(5);
        let x: Vec<f32> = (0..1031).map(|_| rng.normal() as f32).collect();
        let y: Vec<f32> = (0..1031).map(|_| rng.normal() as f32).collect();
        let d1 = dot_f32(&x, &y) as f64;
        let d2 = dot(&x, &y);
        assert!((d1 - d2).abs() < 1e-2 * (1.0 + d2.abs()));
    }

    #[test]
    fn mat_row_access() {
        let m = Mat::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(m.row(1), &[4., 5., 6.]);
        assert_eq!(m.at(0, 2), 3.0);
    }
}
