//! Process-wide kernel implementation selector: `scalar` | `tiled`.
//!
//! Every hot kernel in the crate ships as a twin pair — a plain scalar
//! reference and a block-tiled, autovectorization-friendly rewrite — that
//! implement the SAME fixed blocked reduction order, so the two modes are
//! **bit-identical** end to end (pinned by
//! `rust/tests/kernel_equivalence.rs` over all nine algorithms).  The
//! knob is therefore a pure wall-clock dial, exactly like `threads` /
//! `server_shards`: flipping it never changes a trace, a golden, or a
//! recorded artifact.
//!
//! The twins live next to each other in their home modules and both stay
//! `pub`, so the differential harness tests them against each other
//! directly, without flipping the global:
//!
//! * [`crate::util::tensor`] — `dot_f32_{scalar,tiled}`,
//!   `axpy_{scalar,tiled}`, `gemm_a_bt_{scalar,tiled}`
//! * [`crate::util::bitio`] — `pack_codes_{scalar,tiled}`,
//!   `unpack_codes_into_{scalar,tiled}`
//! * [`crate::quant::innovation`] — `quantize_into_{scalar,tiled}`,
//!   `dequantize_into_{scalar,tiled}`
//! * [`crate::coordinator::server`] — the fused
//!   `absorb_{dense,innovation,fresh}_range_{scalar,tiled}` sweeps
//!
//! Resolution order for the mode: explicit [`set_mode`] (the config /
//! CLI `kernels` knob, applied by `Trainer::assemble`), else the
//! `LAQ_KERNELS` environment variable on first use, else `tiled`.
//! The global is process-wide mutable state — safe precisely because the
//! modes are bit-identical; tests that flip it for contrast must
//! serialize around it (see `kernel_equivalence.rs`).

use std::sync::atomic::{AtomicU8, Ordering};

use crate::{Error, Result};

/// Which member of each kernel twin pair executes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelMode {
    /// Plain scalar reference loops — the differential-test anchor.
    Scalar,
    /// Block-tiled rewrites (register blocking + cache tiling), same
    /// pinned reduction order. The default.
    Tiled,
}

impl KernelMode {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "scalar" => Ok(KernelMode::Scalar),
            "tiled" => Ok(KernelMode::Tiled),
            other => Err(Error::Config(format!(
                "unknown kernels mode '{other}' (expected \"scalar\" | \"tiled\")"
            ))),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            KernelMode::Scalar => "scalar",
            KernelMode::Tiled => "tiled",
        }
    }
}

const UNSET: u8 = 0;
const SCALAR: u8 = 1;
const TILED: u8 = 2;

static MODE: AtomicU8 = AtomicU8::new(UNSET);

/// The active mode.  One relaxed atomic load — cheap enough for kernel
/// entry points that dispatch once per call (never per element).
#[inline]
pub fn mode() -> KernelMode {
    match MODE.load(Ordering::Relaxed) {
        SCALAR => KernelMode::Scalar,
        TILED => KernelMode::Tiled,
        _ => init_from_env(),
    }
}

#[cold]
fn init_from_env() -> KernelMode {
    let m = std::env::var("LAQ_KERNELS")
        .ok()
        .and_then(|v| KernelMode::parse(&v).ok())
        .unwrap_or(KernelMode::Tiled);
    set_mode(m);
    m
}

/// Pin the process-wide mode (config/CLI wins over the env default).
pub fn set_mode(m: KernelMode) {
    let v = match m {
        KernelMode::Scalar => SCALAR,
        KernelMode::Tiled => TILED,
    };
    MODE.store(v, Ordering::Relaxed);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_and_name_roundtrip() {
        for m in [KernelMode::Scalar, KernelMode::Tiled] {
            assert_eq!(KernelMode::parse(m.name()).unwrap(), m);
        }
        assert!(KernelMode::parse("simd").is_err());
        assert!(KernelMode::parse("").is_err());
    }

    #[test]
    fn mode_resolves_and_set_wins() {
        // whatever the env said, an explicit set_mode is observable; then
        // restore the default so parallel tests see the usual tiled mode
        let before = mode();
        set_mode(KernelMode::Tiled);
        assert_eq!(mode(), KernelMode::Tiled);
        set_mode(before);
    }
}
