//! Minimal work-pool substrate (tokio/rayon unavailable offline).
//!
//! The coordinator fans one closure out per worker each iteration and
//! joins the results — a scoped scatter/gather.  `Pool` keeps N OS threads
//! alive across iterations (spawning threads per step would dominate the
//! hot loop) and runs `'static`-free borrows safely via `std::thread::scope`
//! under the hood of [`Pool::scatter`].

use std::sync::mpsc;
use std::sync::{Arc, Mutex};

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Long-lived pool of worker threads executing boxed jobs.
pub struct Pool {
    tx: Option<mpsc::Sender<Job>>,
    handles: Vec<std::thread::JoinHandle<()>>,
    size: usize,
}

impl Pool {
    pub fn new(size: usize) -> Self {
        assert!(size > 0);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let handles = (0..size)
            .map(|i| {
                let rx = Arc::clone(&rx);
                std::thread::Builder::new()
                    .name(format!("laq-pool-{i}"))
                    .spawn(move || loop {
                        let job = {
                            let guard = rx.lock().unwrap();
                            guard.recv()
                        };
                        match job {
                            Ok(job) => job(),
                            Err(_) => break,
                        }
                    })
                    .expect("spawn pool thread")
            })
            .collect();
        Self { tx: Some(tx), handles, size }
    }

    pub fn size(&self) -> usize {
        self.size
    }

    /// Run `f(i)` for each i in 0..n on the pool, collecting results in
    /// index order.  Blocks until all complete.  `f` only needs to be
    /// `Send + Sync` for the duration of the call (we transmute lifetimes
    /// behind a scope-join, like crossbeam's scoped threads).
    pub fn scatter<T, F>(&self, n: usize, f: F) -> Vec<T>
    where
        T: Send + 'static,
        F: Fn(usize) -> T + Sync,
    {
        if n == 0 {
            return Vec::new();
        }
        let (done_tx, done_rx) = mpsc::channel::<(usize, T)>();
        // SAFETY: we join all `n` jobs via `done_rx` below before
        // returning, so the borrow of `f` cannot outlive this frame.
        let f_ptr: &(dyn Fn(usize) -> T + Sync) = &f;
        let f_static: &'static (dyn Fn(usize) -> T + Sync) =
            unsafe { std::mem::transmute(f_ptr) };
        for i in 0..n {
            let done = done_tx.clone();
            let job: Job = Box::new(move || {
                let out = f_static(i);
                let _ = done.send((i, out));
            });
            self.tx.as_ref().unwrap().send(job).expect("pool alive");
        }
        drop(done_tx);
        let mut slots: Vec<Option<T>> = (0..n).map(|_| None).collect();
        for _ in 0..n {
            let (i, v) = done_rx.recv().expect("job completed");
            slots[i] = Some(v);
        }
        slots.into_iter().map(|s| s.unwrap()).collect()
    }
}

impl Drop for Pool {
    fn drop(&mut self) {
        drop(self.tx.take());
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Process-wide shared pool for data-parallel kernels (gradient chunk
/// evaluation).  Sized to the machine once, reused by every worker — the
/// per-iteration cost is just job dispatch, no thread spawning.
pub fn global() -> &'static Pool {
    static POOL: std::sync::OnceLock<Pool> = std::sync::OnceLock::new();
    POOL.get_or_init(|| {
        let n = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(4)
            .min(16);
        Pool::new(n)
    })
}

/// One-shot scoped parallel map (no persistent pool) for cold paths.
pub fn par_map<T, F>(n: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    std::thread::scope(|s| {
        for (i, slot) in out.iter_mut().enumerate() {
            let f = &f;
            s.spawn(move || {
                *slot = Some(f(i));
            });
        }
    });
    out.into_iter().map(|s| s.unwrap()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn scatter_returns_in_index_order() {
        let pool = Pool::new(4);
        let out = pool.scatter(16, |i| i * i);
        assert_eq!(out, (0..16).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn scatter_borrows_environment() {
        let pool = Pool::new(3);
        let data: Vec<f64> = (0..100).map(|i| i as f64).collect();
        let sums = pool.scatter(10, |i| {
            data[i * 10..(i + 1) * 10].iter().sum::<f64>()
        });
        let total: f64 = sums.iter().sum();
        assert_eq!(total, 4950.0);
    }

    #[test]
    fn scatter_runs_everything_exactly_once() {
        let pool = Pool::new(2);
        let counter = AtomicUsize::new(0);
        let out = pool.scatter(50, |_| {
            counter.fetch_add(1, Ordering::SeqCst);
            1usize
        });
        assert_eq!(out.len(), 50);
        assert_eq!(counter.load(Ordering::SeqCst), 50);
    }

    #[test]
    fn reuse_across_calls() {
        let pool = Pool::new(2);
        for round in 0..5 {
            let v = pool.scatter(4, move |i| i + round);
            assert_eq!(v, (0..4).map(|i| i + round).collect::<Vec<_>>());
        }
    }

    #[test]
    fn zero_jobs_is_fine() {
        let pool = Pool::new(1);
        let v: Vec<usize> = pool.scatter(0, |i| i);
        assert!(v.is_empty());
    }

    #[test]
    fn par_map_matches_serial() {
        let v = par_map(8, |i| i * 3);
        assert_eq!(v, (0..8).map(|i| i * 3).collect::<Vec<_>>());
    }
}
