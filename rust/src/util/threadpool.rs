//! Minimal work-pool substrate (tokio/rayon unavailable offline).
//!
//! The coordinator fans one closure out per worker each iteration and
//! joins the results — a scoped scatter/gather.  `Pool` keeps N OS threads
//! alive across iterations (spawning threads per step would dominate the
//! hot loop) and runs `'static`-free borrows safely via `std::thread::scope`
//! under the hood of [`Pool::scatter`].

use std::sync::mpsc;
use std::sync::{Arc, Mutex};

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Long-lived pool of worker threads executing boxed jobs.
pub struct Pool {
    tx: Option<mpsc::Sender<Job>>,
    handles: Vec<std::thread::JoinHandle<()>>,
    size: usize,
}

impl Pool {
    pub fn new(size: usize) -> Self {
        assert!(size > 0);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let handles = (0..size)
            .map(|i| {
                let rx = Arc::clone(&rx);
                std::thread::Builder::new()
                    .name(format!("laq-pool-{i}"))
                    .spawn(move || loop {
                        let job = {
                            let guard = rx.lock().unwrap();
                            guard.recv()
                        };
                        match job {
                            Ok(job) => job(),
                            Err(_) => break,
                        }
                    })
                    .expect("spawn pool thread")
            })
            .collect();
        Self { tx: Some(tx), handles, size }
    }

    pub fn size(&self) -> usize {
        self.size
    }

    /// Run `f(i)` for each i in 0..n on the pool, collecting results in
    /// index order.  Blocks until all complete.  `f` only needs to be
    /// `Send + Sync` for the duration of the call (we transmute lifetimes
    /// behind a scope-join, like crossbeam's scoped threads).
    ///
    /// A panic inside a job is caught on the pool thread (which survives
    /// to serve later scatters), held until **all** `n` jobs have
    /// finished — the join is what makes the lifetime transmute sound, so
    /// it must complete even on the failure path — and then re-raised
    /// here with the original payload.
    pub fn scatter<T, F>(&self, n: usize, f: F) -> Vec<T>
    where
        T: Send + 'static,
        F: Fn(usize) -> T + Sync,
    {
        if n == 0 {
            return Vec::new();
        }
        type JobResult<T> = std::thread::Result<T>;
        let (done_tx, done_rx) = mpsc::channel::<(usize, JobResult<T>)>();
        // SAFETY: we join all `n` jobs via `done_rx` below before
        // returning (or unwinding), so the borrow of `f` cannot outlive
        // this frame.
        let f_ptr: &(dyn Fn(usize) -> T + Sync) = &f;
        let f_static: &'static (dyn Fn(usize) -> T + Sync) =
            unsafe { std::mem::transmute(f_ptr) };
        for i in 0..n {
            let done = done_tx.clone();
            let job: Job = Box::new(move || {
                // AssertUnwindSafe: on Err we re-raise in the caller
                // after the join, same observability as an uncaught panic
                let out = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    f_static(i)
                }));
                let _ = done.send((i, out));
            });
            self.tx.as_ref().unwrap().send(job).expect("pool alive");
        }
        drop(done_tx);
        let mut slots: Vec<Option<T>> = (0..n).map(|_| None).collect();
        let mut first_panic = None;
        for _ in 0..n {
            // every job sends exactly once (panics are caught above), so
            // recv cannot fail before all n results arrive
            let (i, v) = done_rx.recv().expect("job completed");
            match v {
                Ok(v) => slots[i] = Some(v),
                Err(p) => {
                    if first_panic.is_none() {
                        first_panic = Some(p);
                    }
                }
            }
        }
        if let Some(p) = first_panic {
            std::panic::resume_unwind(p);
        }
        slots.into_iter().map(|s| s.unwrap()).collect()
    }
}

impl Drop for Pool {
    fn drop(&mut self) {
        drop(self.tx.take());
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Raw base pointer into a slice, sendable across the pool's threads so a
/// scatter can hand each job *disjoint* `&mut` access to one element
/// (`&mut [T]` itself cannot be captured by a `Fn` closure).
///
/// SAFETY contract for [`SendPtr::get_mut`]: the caller must guarantee
/// that (1) every index is dereferenced by at most one thread at a time —
/// [`Pool::scatter`] provides this, since it runs each index exactly once
/// — (2) indices stay within the originating slice, and (3) the slice
/// outlives the scatter (the scatter's join provides this) with no other
/// live borrows of it for the duration.
pub struct SendPtr<T>(*mut T);

unsafe impl<T: Send> Send for SendPtr<T> {}
unsafe impl<T: Send> Sync for SendPtr<T> {}

impl<T> Clone for SendPtr<T> {
    fn clone(&self) -> Self {
        *self
    }
}

impl<T> Copy for SendPtr<T> {}

impl<T: Send> SendPtr<T> {
    pub fn new(slice: &mut [T]) -> Self {
        Self(slice.as_mut_ptr())
    }

    /// # Safety
    /// See the type-level contract: disjoint indices, in bounds, source
    /// slice alive and otherwise unborrowed.
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn get_mut(&self, i: usize) -> &mut T {
        &mut *self.0.add(i)
    }
}

/// Process-wide shared pool for data-parallel kernels (gradient chunk
/// evaluation).  Sized to the machine once, reused by every worker — the
/// per-iteration cost is just job dispatch, no thread spawning.
pub fn global() -> &'static Pool {
    static POOL: std::sync::OnceLock<Pool> = std::sync::OnceLock::new();
    POOL.get_or_init(|| {
        let n = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(4)
            .min(16);
        Pool::new(n)
    })
}

/// One-shot scoped parallel map (no persistent pool) for cold paths.
pub fn par_map<T, F>(n: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    std::thread::scope(|s| {
        for (i, slot) in out.iter_mut().enumerate() {
            let f = &f;
            s.spawn(move || {
                *slot = Some(f(i));
            });
        }
    });
    out.into_iter().map(|s| s.unwrap()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn scatter_returns_in_index_order() {
        let pool = Pool::new(4);
        let out = pool.scatter(16, |i| i * i);
        assert_eq!(out, (0..16).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn scatter_borrows_environment() {
        let pool = Pool::new(3);
        let data: Vec<f64> = (0..100).map(|i| i as f64).collect();
        let sums = pool.scatter(10, |i| {
            data[i * 10..(i + 1) * 10].iter().sum::<f64>()
        });
        let total: f64 = sums.iter().sum();
        assert_eq!(total, 4950.0);
    }

    #[test]
    fn scatter_runs_everything_exactly_once() {
        let pool = Pool::new(2);
        let counter = AtomicUsize::new(0);
        let out = pool.scatter(50, |_| {
            counter.fetch_add(1, Ordering::SeqCst);
            1usize
        });
        assert_eq!(out.len(), 50);
        assert_eq!(counter.load(Ordering::SeqCst), 50);
    }

    #[test]
    fn reuse_across_calls() {
        let pool = Pool::new(2);
        for round in 0..5 {
            let v = pool.scatter(4, move |i| i + round);
            assert_eq!(v, (0..4).map(|i| i + round).collect::<Vec<_>>());
        }
    }

    #[test]
    fn zero_jobs_is_fine() {
        let pool = Pool::new(1);
        let v: Vec<usize> = pool.scatter(0, |i| i);
        assert!(v.is_empty());
    }

    #[test]
    fn scatter_propagates_job_panics_and_pool_survives() {
        let pool = Pool::new(2);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.scatter(4, |i| {
                if i == 2 {
                    panic!("boom");
                }
                i
            })
        }));
        assert!(result.is_err(), "job panic must reach the caller");
        // the pool threads survived and keep serving jobs
        let v = pool.scatter(3, |i| i + 1);
        assert_eq!(v, vec![1, 2, 3]);
    }

    #[test]
    fn par_map_matches_serial() {
        let v = par_map(8, |i| i * 3);
        assert_eq!(v, (0..8).map(|i| i * 3).collect::<Vec<_>>());
    }

    #[test]
    fn send_ptr_gives_disjoint_mutable_access() {
        let pool = Pool::new(4);
        let mut data: Vec<Vec<u64>> = (0..32).map(|i| vec![i as u64]).collect();
        let ptr = SendPtr::new(&mut data[..]);
        let lens = pool.scatter(32, move |i| {
            // SAFETY: scatter runs each index exactly once; `data` is
            // alive and unborrowed until the scatter joins below.
            let v = unsafe { ptr.get_mut(i) };
            v.push(i as u64 * 2);
            v.len()
        });
        assert!(lens.iter().all(|&l| l == 2));
        for (i, v) in data.iter().enumerate() {
            assert_eq!(v, &vec![i as u64, i as u64 * 2]);
        }
    }

    #[test]
    fn nested_scatter_on_distinct_pools_completes() {
        // the trainer's worker fan-out runs on its own pool while the
        // model layer scatters row chunks onto the global pool from
        // inside those jobs — distinct pools, so no job-waits-on-job
        // deadlock is possible
        let outer = Pool::new(3);
        let out = outer.scatter(6, |i| {
            let inner: Vec<usize> = global().scatter(4, move |j| i * 10 + j);
            inner.into_iter().sum::<usize>()
        });
        assert_eq!(out.len(), 6);
        for (i, s) in out.iter().enumerate() {
            assert_eq!(*s, i * 40 + 6);
        }
    }
}
