//! Minimal work-pool substrate (tokio/rayon unavailable offline).
//!
//! Two fan-out layers use this pool every iteration: the trainer scatters
//! one job per *worker* (local phase) and the sharded server scatters one
//! job per *θ-shard* (absorb/apply).  Both run in the hot loop, so the
//! dispatch path is engineered around two properties:
//!
//! * **Zero steady-state allocation** — [`Pool::run_indexed`] publishes a
//!   stack-held batch descriptor into a retained `VecDeque` and hands out
//!   indices under a mutex; no per-job boxing, no channel nodes.  After
//!   the queue's capacity warms up, a scatter performs no heap traffic at
//!   all (this is what the counting-allocator test in
//!   `rust/tests/alloc_steady_state.rs` pins).
//! * **Caller participation** — the thread that posts a batch claims and
//!   runs jobs itself instead of sleeping, so a pool of `T` spawned
//!   threads gives `T + 1` runners.  On small machines this is the
//!   difference between 2× and 1.5× on a two-way split.
//!
//! `'static`-free borrows are safe via the join-before-return discipline
//! (like crossbeam's scoped threads): a batch cannot leave the queue until
//! every claimed job has finished, and `run_indexed` does not return until
//! the batch has left the queue.
//!
//! One batch nests inside another only across *distinct* pools (the
//! trainer pool, each server's shard pool, and the global model pool are
//! separate objects).  Posting a batch to a pool from inside one of that
//! same pool's jobs would deadlock — none of the in-tree layers do this.

use std::cell::UnsafeCell;
use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};

/// A published fan-out: `f` is run once per index in `0..n`.
struct Batch {
    /// lifetime-erased job (SAFETY: outlives the batch via join-before-return)
    f: *const (dyn Fn(usize) + Sync),
    n: usize,
    /// next claim position (guarded by the pool mutex)
    next: usize,
    /// optional claim order: position `p` claims job `order[p]` (null =
    /// identity).  Streaming callers use this to start jobs in the order a
    /// downstream consumer will want their results (SAFETY: outlives the
    /// batch via join-before-return, like `f`)
    order: *const usize,
    /// claimed-or-unclaimed jobs not yet finished
    remaining: usize,
    /// first panic payload, re-raised by the posting thread after the join
    panic: Option<Box<dyn std::any::Any + Send>>,
}

impl Batch {
    /// Claim the next job index, mapping through the claim order.  Must
    /// be called with the pool mutex held.
    fn claim(&mut self) -> Option<usize> {
        if self.next >= self.n {
            return None;
        }
        let pos = self.next;
        self.next += 1;
        Some(if self.order.is_null() {
            pos
        } else {
            // SAFETY: order slices outlive their batch (join-before-return)
            // and have length n
            unsafe { *self.order.add(pos) }
        })
    }
}

/// Raw pointer to a stack-held [`Batch`], movable across pool threads.
/// All dereferences happen with the pool mutex held, and the batch is
/// removed from the queue before the posting frame returns.
#[derive(Clone, Copy)]
struct BatchPtr(*mut Batch);

unsafe impl Send for BatchPtr {}

struct Shared {
    queue: VecDeque<BatchPtr>,
    shutdown: bool,
}

struct Inner {
    state: Mutex<Shared>,
    /// workers wait here for new batches
    work_cv: Condvar,
    /// posting threads wait here for their batch to drain
    done_cv: Condvar,
}

/// Long-lived pool of worker threads executing indexed fan-outs.
pub struct Pool {
    inner: Arc<Inner>,
    handles: Vec<std::thread::JoinHandle<()>>,
    size: usize,
}

/// Book-keep one completed job: record the first panic payload, decrement
/// the batch's remaining count and — on the last job — retire the batch
/// from the queue and wake any posting threads.  Shared by the pool
/// workers and the posting thread's participation loop so the two runners
/// can never drift apart.
fn finish_job(inner: &Inner, bp: BatchPtr, out: std::thread::Result<()>) {
    let mut guard = inner.state.lock().unwrap();
    // SAFETY: batch pointers are only dereferenced under the pool mutex
    // and stay valid until their last job completes (which is at the
    // earliest this very call)
    let b = unsafe { &mut *bp.0 };
    if let Err(p) = out {
        if b.panic.is_none() {
            b.panic = Some(p);
        }
    }
    b.remaining -= 1;
    if b.remaining == 0 {
        guard.queue.retain(|q| !std::ptr::eq(q.0, bp.0));
        inner.done_cv.notify_all();
    }
}

fn worker_loop(inner: &Inner) {
    let mut guard = inner.state.lock().unwrap();
    loop {
        if guard.shutdown {
            return;
        }
        // claim the first unclaimed index in FIFO batch order
        let mut claimed: Option<(BatchPtr, usize)> = None;
        for &bp in guard.queue.iter() {
            // SAFETY: dereferenced under the pool mutex (see finish_job)
            let b = unsafe { &mut *bp.0 };
            if let Some(i) = b.claim() {
                claimed = Some((bp, i));
                break;
            }
        }
        match claimed {
            Some((bp, i)) => {
                let f = unsafe { (*bp.0).f };
                drop(guard);
                // AssertUnwindSafe: on Err the payload is re-raised in the
                // posting thread after the join, same observability as an
                // uncaught panic
                let out = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    (unsafe { &*f })(i)
                }));
                finish_job(inner, bp, out);
                guard = inner.state.lock().unwrap();
            }
            None => {
                guard = inner.work_cv.wait(guard).unwrap();
            }
        }
    }
}

impl Pool {
    pub fn new(size: usize) -> Self {
        assert!(size > 0);
        let inner = Arc::new(Inner {
            state: Mutex::new(Shared { queue: VecDeque::new(), shutdown: false }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
        });
        let handles = (0..size)
            .map(|i| {
                let inner = Arc::clone(&inner);
                std::thread::Builder::new()
                    .name(format!("laq-pool-{i}"))
                    .spawn(move || worker_loop(&inner))
                    .expect("spawn pool thread")
            })
            .collect();
        Self { inner, handles, size }
    }

    /// Spawned worker-thread count (the posting thread adds one more
    /// runner during [`Self::run_indexed`]).
    pub fn size(&self) -> usize {
        self.size
    }

    /// Run `f(i)` for every `i in 0..n` across the pool *and* the calling
    /// thread, blocking until all complete.  `f` only needs to be
    /// `Sync` for the duration of the call (lifetime-transmuted behind a
    /// join, like crossbeam's scoped threads).  Performs no steady-state
    /// heap allocation: the batch descriptor lives on this stack frame and
    /// the shared queue retains its capacity across calls.
    ///
    /// A panic inside a job is caught on whichever thread ran it, held
    /// until **all** `n` jobs have finished — the join is what makes the
    /// lifetime transmute sound, so it must complete even on the failure
    /// path — and then re-raised here with the original payload.
    pub fn run_indexed(&self, n: usize, f: &(dyn Fn(usize) + Sync)) {
        if n == 0 {
            return;
        }
        // SAFETY: we join the whole batch below before returning (or
        // unwinding), so the borrow of `f` cannot outlive this frame.
        let f_static: &'static (dyn Fn(usize) + Sync) = unsafe { std::mem::transmute(f) };
        let batch = UnsafeCell::new(Batch {
            f: f_static as *const (dyn Fn(usize) + Sync),
            n,
            next: 0,
            order: std::ptr::null(),
            remaining: n,
            panic: None,
        });
        let bp = BatchPtr(batch.get());
        let inner = &*self.inner;
        {
            let mut guard = inner.state.lock().unwrap();
            guard.queue.push_back(bp);
        }
        if n > 1 {
            inner.work_cv.notify_all();
        }
        // caller participates: claim from our own batch until it drains
        loop {
            let mut guard = inner.state.lock().unwrap();
            let b = unsafe { &mut *bp.0 };
            match b.claim() {
                None => {
                    // nothing left to claim; wait for in-flight jobs
                    while unsafe { &*bp.0 }.remaining > 0 {
                        guard = inner.done_cv.wait(guard).unwrap();
                    }
                    // remaining == 0 implies the batch already left the queue
                    let p = unsafe { &mut *bp.0 }.panic.take();
                    drop(guard);
                    if let Some(p) = p {
                        std::panic::resume_unwind(p);
                    }
                    return;
                }
                Some(i) => {
                    drop(guard);
                    let out =
                        std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(i)));
                    finish_job(inner, bp, out);
                }
            }
        }
    }

    /// Run `f(i)` for each i in 0..n, collecting results in index order.
    /// Blocks until all complete.  Allocates the result vector (use
    /// [`Self::run_indexed`] with retained slots on allocation-free paths).
    pub fn scatter<T, F>(&self, n: usize, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(usize) -> T + Sync,
    {
        let mut slots: Vec<Option<T>> = (0..n).map(|_| None).collect();
        {
            let ptr = SendPtr::new(&mut slots[..]);
            self.run_indexed(n, &|i| {
                // SAFETY: run_indexed hands out each index exactly once,
                // and `slots` outlives the join
                let slot = unsafe { ptr.get_mut(i) };
                *slot = Some(f(i));
            });
        }
        slots.into_iter().map(|s| s.expect("job completed")).collect()
    }
}

/// A **retained, reusable** stream-batch descriptor: a streaming /
/// completion-order counterpart to [`Pool::run_indexed`] whose heap
/// descriptor is allocated once, owned by the caller (the trainer keeps
/// one across `step` calls; it outlives any single step), and refilled in
/// place by every [`Self::post`] — so a hot loop posts a streaming
/// fan-out every iteration with **zero steady-state allocation**.
///
/// `post` publishes `n` jobs which the pool's threads claim in a given
/// order while the **caller does not participate** — it is free to
/// consume results concurrently as the jobs publish them out-of-band
/// (e.g. an atomic readiness flag per index; the async wire phase's
/// coordinator absorbs uploads while later workers are still computing).
/// The returned [`BatchGuard`]'s join (explicit or on drop) blocks until
/// every job finished.  The guard mutably borrows the `StreamBatch`, so a
/// second post before the previous join is a compile error, and the
/// lifetime-erased borrows of `f`/`order` are sound by the same
/// join-before-return discipline as the rest of this module.  Leaking the
/// guard (`std::mem::forget`) breaks that contract — don't.
pub struct StreamBatch {
    /// heap-held so the queue's pointer stays valid wherever the owning
    /// struct moves between posts
    batch: Box<UnsafeCell<Batch>>,
}

/// SAFETY: the erased `f`/`order` pointers inside are only dereferenced
/// by pool threads between a `post` and its guard's join — a window in
/// which the borrowed closure's frame is pinned by the guard.  Between
/// windows the batch is retired (`remaining == 0`, not in any queue) and
/// the stale pointers are never read, so moving the descriptor across
/// threads is sound.
unsafe impl Send for StreamBatch {}

fn noop_job(_: usize) {}

impl Default for StreamBatch {
    fn default() -> Self {
        Self::new()
    }
}

impl StreamBatch {
    pub fn new() -> Self {
        // inert placeholder: a retired batch (n == 0) is never claimed,
        // so this pointer is replaced by the first post before any deref
        let noop: &'static (dyn Fn(usize) + Sync) = &noop_job;
        Self {
            batch: Box::new(UnsafeCell::new(Batch {
                f: noop as *const (dyn Fn(usize) + Sync),
                n: 0,
                next: 0,
                order: std::ptr::null(),
                remaining: 0,
                panic: None,
            })),
        }
    }

    /// Post `n` jobs onto `pool` through this retained descriptor; the
    /// pool's threads claim them in `order` (a permutation of `0..n`;
    /// `None` = index order) while the caller is free to consume results
    /// out-of-band.  No per-post heap allocation.
    pub fn post<'a>(
        &'a mut self,
        pool: &'a Pool,
        n: usize,
        order: Option<&'a [usize]>,
        f: &'a (dyn Fn(usize) + Sync),
    ) -> BatchGuard<'a> {
        if let Some(o) = order {
            assert_eq!(o.len(), n, "claim order must cover every job");
        }
        {
            // SAFETY: &mut self guarantees no guard is alive, and a
            // retired batch (remaining == 0) is in no queue — we are the
            // only referent.
            let b = unsafe { &mut *self.batch.get() };
            assert_eq!(b.remaining, 0, "previous post not joined");
            // SAFETY: the returned guard joins the batch before 'a ends
            // (join or Drop), so the borrow of `f` cannot outlive it.
            let f_static: &'static (dyn Fn(usize) + Sync) = unsafe { std::mem::transmute(f) };
            *b = Batch {
                f: f_static as *const (dyn Fn(usize) + Sync),
                n,
                next: 0,
                order: order.map_or(std::ptr::null(), |o| o.as_ptr()),
                remaining: n,
                panic: None,
            };
        }
        let guard = BatchGuard {
            inner: &*pool.inner,
            batch: &*self.batch,
            joined: n == 0,
        };
        if n > 0 {
            let bp = BatchPtr(guard.batch.get());
            {
                let mut st = pool.inner.state.lock().unwrap();
                st.queue.push_back(bp);
            }
            pool.inner.work_cv.notify_all();
        }
        guard
    }
}

/// The in-flight half of a [`StreamBatch::post`] — joins the batch on
/// [`Self::join`] or on drop, re-raising the first job panic.
pub struct BatchGuard<'a> {
    inner: &'a Inner,
    batch: &'a UnsafeCell<Batch>,
    joined: bool,
}

impl BatchGuard<'_> {
    fn join_inner(&mut self) -> Option<Box<dyn std::any::Any + Send>> {
        if self.joined {
            return None;
        }
        self.joined = true;
        let bp = self.batch.get();
        let mut guard = self.inner.state.lock().unwrap();
        // SAFETY: batch pointers are only dereferenced under the pool
        // mutex; the retained descriptor outlives this guard
        while unsafe { &*bp }.remaining > 0 {
            guard = self.inner.done_cv.wait(guard).unwrap();
        }
        // remaining == 0 implies finish_job already retired the batch
        // from the queue, so no worker can still hold our pointer
        let p = unsafe { &mut *bp }.panic.take();
        drop(guard);
        p
    }

    /// Block until every job in the batch has finished; re-raises the
    /// first job panic with its original payload.
    pub fn join(mut self) {
        if let Some(p) = self.join_inner() {
            std::panic::resume_unwind(p);
        }
    }
}

impl Drop for BatchGuard<'_> {
    fn drop(&mut self) {
        let p = self.join_inner();
        if let Some(p) = p {
            if !std::thread::panicking() {
                std::panic::resume_unwind(p);
            }
        }
    }
}

impl std::fmt::Debug for Pool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Pool").field("size", &self.size).finish()
    }
}

impl Drop for Pool {
    fn drop(&mut self) {
        {
            let mut guard = self.inner.state.lock().unwrap();
            guard.shutdown = true;
        }
        self.inner.work_cv.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Raw base pointer into a slice, sendable across the pool's threads so a
/// fan-out can hand each job *disjoint* `&mut` access to one element or
/// one contiguous range (`&mut [T]` itself cannot be captured by a `Fn`
/// closure).
///
/// SAFETY contract for [`SendPtr::get_mut`] / [`SendPtr::slice_mut`]: the
/// caller must guarantee that (1) every index is dereferenced by at most
/// one thread at a time — [`Pool::run_indexed`] provides this, since it
/// hands out each index exactly once — (2) indices/ranges stay within the
/// originating slice and ranges handed to different jobs are disjoint, and
/// (3) the slice outlives the fan-out (the join provides this) with no
/// other live borrows of it for the duration.
pub struct SendPtr<T>(*mut T);

unsafe impl<T: Send> Send for SendPtr<T> {}
unsafe impl<T: Send> Sync for SendPtr<T> {}

impl<T> std::fmt::Debug for SendPtr<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "SendPtr({:p})", self.0)
    }
}

impl<T> Clone for SendPtr<T> {
    fn clone(&self) -> Self {
        *self
    }
}

impl<T> Copy for SendPtr<T> {}

impl<T: Send> SendPtr<T> {
    pub fn new(slice: &mut [T]) -> Self {
        Self(slice.as_mut_ptr())
    }

    /// # Safety
    /// See the type-level contract: disjoint indices, in bounds, source
    /// slice alive and otherwise unborrowed.
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn get_mut(&self, i: usize) -> &mut T {
        &mut *self.0.add(i)
    }

    /// Disjoint mutable sub-slice `[start, start + len)` — the shard
    /// access primitive.
    ///
    /// # Safety
    /// See the type-level contract: ranges handed to concurrent jobs must
    /// not overlap, stay in bounds, and the source slice must outlive the
    /// fan-out with no other live borrows.
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn slice_mut(&self, start: usize, len: usize) -> &mut [T] {
        std::slice::from_raw_parts_mut(self.0.add(start), len)
    }

    /// Shared reference to element `i` — for readers that consume a slot
    /// after its exclusive writer has published completion (e.g. the
    /// async absorber reading a wire slot once the worker's readiness
    /// flag is set with Release ordering).
    ///
    /// # Safety
    /// Same contract as [`Self::get_mut`], relaxed to allow concurrent
    /// *shared* reads of the same index provided no thread mutates it for
    /// the duration, and the read is ordered after the writer's release.
    pub unsafe fn get_ref(&self, i: usize) -> &T {
        &*self.0.add(i)
    }
}

/// Process-wide shared pool for data-parallel kernels (gradient chunk
/// evaluation).  Sized to the machine once, reused by every worker — the
/// per-iteration cost is just job dispatch, no thread spawning.
pub fn global() -> &'static Pool {
    static POOL: std::sync::OnceLock<Pool> = std::sync::OnceLock::new();
    POOL.get_or_init(|| {
        let n = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(4)
            .min(16);
        Pool::new(n)
    })
}

/// One-shot scoped parallel map (no persistent pool) for cold paths.
pub fn par_map<T, F>(n: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    std::thread::scope(|s| {
        for (i, slot) in out.iter_mut().enumerate() {
            let f = &f;
            s.spawn(move || {
                *slot = Some(f(i));
            });
        }
    });
    out.into_iter().map(|s| s.unwrap()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn scatter_returns_in_index_order() {
        let pool = Pool::new(4);
        let out = pool.scatter(16, |i| i * i);
        assert_eq!(out, (0..16).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn scatter_borrows_environment() {
        let pool = Pool::new(3);
        let data: Vec<f64> = (0..100).map(|i| i as f64).collect();
        let sums = pool.scatter(10, |i| {
            data[i * 10..(i + 1) * 10].iter().sum::<f64>()
        });
        let total: f64 = sums.iter().sum();
        assert_eq!(total, 4950.0);
    }

    #[test]
    fn scatter_runs_everything_exactly_once() {
        let pool = Pool::new(2);
        let counter = AtomicUsize::new(0);
        let out = pool.scatter(50, |_| {
            counter.fetch_add(1, Ordering::SeqCst);
            1usize
        });
        assert_eq!(out.len(), 50);
        assert_eq!(counter.load(Ordering::SeqCst), 50);
    }

    #[test]
    fn run_indexed_covers_every_index_once() {
        let pool = Pool::new(3);
        let hits: Vec<AtomicUsize> = (0..64).map(|_| AtomicUsize::new(0)).collect();
        pool.run_indexed(64, &|i| {
            hits[i].fetch_add(1, Ordering::SeqCst);
        });
        for (i, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::SeqCst), 1, "index {i}");
        }
    }

    #[test]
    fn run_indexed_disjoint_ranges_via_slice_mut() {
        let pool = Pool::new(2);
        let mut data = vec![0u64; 1000];
        let bounds = [0usize, 300, 650, 1000];
        {
            let ptr = SendPtr::new(&mut data[..]);
            pool.run_indexed(3, &|s| {
                let (lo, hi) = (bounds[s], bounds[s + 1]);
                // SAFETY: ranges from `bounds` are disjoint; `data`
                // outlives the join
                let chunk = unsafe { ptr.slice_mut(lo, hi - lo) };
                for (k, v) in chunk.iter_mut().enumerate() {
                    *v = (lo + k) as u64;
                }
            });
        }
        for (i, v) in data.iter().enumerate() {
            assert_eq!(*v, i as u64);
        }
    }

    #[test]
    fn reuse_across_calls() {
        let pool = Pool::new(2);
        for round in 0..5 {
            let v = pool.scatter(4, move |i| i + round);
            assert_eq!(v, (0..4).map(|i| i + round).collect::<Vec<_>>());
        }
    }

    #[test]
    fn zero_jobs_is_fine() {
        let pool = Pool::new(1);
        let v: Vec<usize> = pool.scatter(0, |i| i);
        assert!(v.is_empty());
        pool.run_indexed(0, &|_| unreachable!());
    }

    #[test]
    fn more_jobs_than_threads_and_vice_versa() {
        let pool = Pool::new(8);
        assert_eq!(pool.scatter(2, |i| i).len(), 2);
        let pool = Pool::new(1);
        assert_eq!(pool.scatter(32, |i| i).len(), 32);
    }

    #[test]
    fn scatter_propagates_job_panics_and_pool_survives() {
        let pool = Pool::new(2);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.scatter(4, |i| {
                if i == 2 {
                    panic!("boom");
                }
                i
            })
        }));
        assert!(result.is_err(), "job panic must reach the caller");
        // the pool threads survived and keep serving jobs
        let v = pool.scatter(3, |i| i + 1);
        assert_eq!(v, vec![1, 2, 3]);
    }

    #[test]
    fn concurrent_batches_from_different_threads() {
        // two threads posting to the same pool: batches queue FIFO and
        // both complete (callers run their own jobs, workers help)
        let pool = std::sync::Arc::new(Pool::new(2));
        let mut joins = Vec::new();
        for t in 0..2u64 {
            let pool = std::sync::Arc::clone(&pool);
            joins.push(std::thread::spawn(move || {
                let out = pool.scatter(20, move |i| i as u64 + t * 1000);
                assert_eq!(out.len(), 20);
                assert_eq!(out[3], 3 + t * 1000);
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
    }

    #[test]
    fn stream_batch_caller_overlaps_while_pool_works() {
        // the posting thread consumes published results while the pool is
        // still working — the async wire phase's shape
        let pool = Pool::new(2);
        let mut batch = StreamBatch::new();
        let done: Vec<AtomicUsize> = (0..16).map(|_| AtomicUsize::new(0)).collect();
        {
            let f = |i: usize| {
                done[i].store(1, Ordering::Release);
            };
            let _guard = batch.post(&pool, 16, None, &f);
            // consume completions out-of-band (spin; jobs are trivial)
            let mut consumed = 0;
            while consumed < 16 {
                consumed = done
                    .iter()
                    .filter(|d| d.load(Ordering::Acquire) == 1)
                    .count();
                std::thread::yield_now();
            }
            // guard dropped here: implicit join
        }
        assert!(done.iter().all(|d| d.load(Ordering::SeqCst) == 1));
        // the pool itself survives and keeps serving
        assert_eq!(pool.scatter(3, |i| i + 1), vec![1, 2, 3]);
    }

    #[test]
    fn stream_batch_is_reusable_across_posts() {
        // the retained descriptor is the zero-alloc engine behind the
        // async wire phases: one allocation at construction, then any
        // number of post/join cycles refill it in place
        let pool = Pool::new(3);
        let mut batch = StreamBatch::new();
        for round in 0..5usize {
            let hits: Vec<AtomicUsize> = (0..16).map(|_| AtomicUsize::new(0)).collect();
            {
                let f = |i: usize| {
                    hits[i].fetch_add(round + 1, Ordering::SeqCst);
                };
                batch.post(&pool, 16, None, &f).join();
            }
            for (i, h) in hits.iter().enumerate() {
                assert_eq!(h.load(Ordering::SeqCst), round + 1, "round {round} index {i}");
            }
        }
        // zero jobs joins trivially and the batch stays reusable
        batch.post(&pool, 0, None, &|_| unreachable!()).join();
        let seen = std::sync::Mutex::new(Vec::new());
        let order = [2usize, 0, 1];
        {
            let single = Pool::new(1);
            let f = |i: usize| seen.lock().unwrap().push(i);
            batch.post(&single, 3, Some(&order[..]), &f).join();
        }
        assert_eq!(*seen.lock().unwrap(), vec![2, 0, 1]);
    }

    #[test]
    fn stream_batch_guard_drop_joins_and_propagates_panics() {
        let pool = Pool::new(2);
        let mut batch = StreamBatch::new();
        let done: Vec<AtomicUsize> = (0..8).map(|_| AtomicUsize::new(0)).collect();
        {
            let f = |i: usize| {
                done[i].store(1, Ordering::Release);
            };
            let _guard = batch.post(&pool, 8, None, &f);
            // guard dropped here: implicit join
        }
        assert!(done.iter().all(|d| d.load(Ordering::SeqCst) == 1));
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let f = |i: usize| {
                if i == 1 {
                    panic!("batch boom");
                }
            };
            batch.post(&pool, 4, None, &f).join();
        }));
        assert!(result.is_err(), "job panic must reach the joining caller");
        // the batch recovered and keeps serving
        batch.post(&pool, 2, None, &|_| {}).join();
    }

    #[test]
    fn par_map_matches_serial() {
        let v = par_map(8, |i| i * 3);
        assert_eq!(v, (0..8).map(|i| i * 3).collect::<Vec<_>>());
    }

    #[test]
    fn send_ptr_gives_disjoint_mutable_access() {
        let pool = Pool::new(4);
        let mut data: Vec<Vec<u64>> = (0..32).map(|i| vec![i as u64]).collect();
        let ptr = SendPtr::new(&mut data[..]);
        let lens = pool.scatter(32, move |i| {
            // SAFETY: each index is handed out exactly once; `data` is
            // alive and unborrowed until the fan-out joins below.
            let v = unsafe { ptr.get_mut(i) };
            v.push(i as u64 * 2);
            v.len()
        });
        assert!(lens.iter().all(|&l| l == 2));
        for (i, v) in data.iter().enumerate() {
            assert_eq!(v, &vec![i as u64, i as u64 * 2]);
        }
    }

    #[test]
    fn nested_scatter_on_distinct_pools_completes() {
        // the trainer's worker fan-out runs on its own pool while the
        // model layer scatters row chunks onto the global pool from
        // inside those jobs — distinct pools, so no job-waits-on-job
        // deadlock is possible (the inner post even helps drain the
        // global pool's batch while it waits)
        let outer = Pool::new(3);
        let out = outer.scatter(6, |i| {
            let inner: Vec<usize> = global().scatter(4, move |j| i * 10 + j);
            inner.into_iter().sum::<usize>()
        });
        assert_eq!(out.len(), 6);
        for (i, s) in out.iter().enumerate() {
            assert_eq!(*s, i * 40 + 6);
        }
    }
}
