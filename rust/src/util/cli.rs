//! Command-line argument parsing substrate (clap unavailable offline).
//!
//! Supports the subcommand + `--flag value` / `--flag=value` / boolean
//! switch style used by the `laq` binary, with typed accessors, defaults,
//! required-argument errors, and an auto-generated usage string.

use std::collections::BTreeMap;

#[derive(Debug, Clone)]
pub struct ArgSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<&'static str>,
    pub is_switch: bool,
}

#[derive(Debug, Default)]
pub struct Args {
    values: BTreeMap<String, String>,
    switches: Vec<String>,
    positional: Vec<String>,
}

#[derive(Debug, PartialEq)]
pub enum CliError {
    UnknownFlag(String),
    MissingValue(String),
    MissingRequired(String),
    Invalid(String, String),
}

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CliError::UnknownFlag(n) => write!(f, "unknown flag --{n}"),
            CliError::MissingValue(n) => write!(f, "flag --{n} requires a value"),
            CliError::MissingRequired(n) => write!(f, "missing required flag --{n}"),
            CliError::Invalid(n, v) => write!(f, "invalid value for --{n}: {v}"),
        }
    }
}

impl std::error::Error for CliError {}

impl Args {
    /// Parse `argv` (without the program name) against `spec`.
    pub fn parse(argv: &[String], spec: &[ArgSpec]) -> Result<Args, CliError> {
        let mut out = Args::default();
        let find = |name: &str| spec.iter().find(|s| s.name == name);
        let mut it = argv.iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(stripped) = tok.strip_prefix("--") {
                let (name, inline) = match stripped.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let s = find(&name).ok_or_else(|| CliError::UnknownFlag(name.clone()))?;
                if s.is_switch {
                    out.switches.push(name);
                } else {
                    let v = match inline {
                        Some(v) => v,
                        None => it
                            .next()
                            .cloned()
                            .ok_or_else(|| CliError::MissingValue(name.clone()))?,
                    };
                    out.values.insert(name, v);
                }
            } else {
                out.positional.push(tok.clone());
            }
        }
        // fill defaults
        for s in spec {
            if !s.is_switch && !out.values.contains_key(s.name) {
                if let Some(d) = s.default {
                    out.values.insert(s.name.to_string(), d.to_string());
                }
            }
        }
        Ok(out)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    pub fn require(&self, name: &str) -> Result<&str, CliError> {
        self.get(name)
            .ok_or_else(|| CliError::MissingRequired(name.to_string()))
    }

    pub fn get_usize(&self, name: &str) -> Result<Option<usize>, CliError> {
        self.get(name)
            .map(|v| {
                v.parse::<usize>()
                    .map_err(|e| CliError::Invalid(name.into(), e.to_string()))
            })
            .transpose()
    }

    pub fn get_f64(&self, name: &str) -> Result<Option<f64>, CliError> {
        self.get(name)
            .map(|v| {
                v.parse::<f64>()
                    .map_err(|e| CliError::Invalid(name.into(), e.to_string()))
            })
            .transpose()
    }

    pub fn get_u64(&self, name: &str) -> Result<Option<u64>, CliError> {
        self.get(name)
            .map(|v| {
                v.parse::<u64>()
                    .map_err(|e| CliError::Invalid(name.into(), e.to_string()))
            })
            .transpose()
    }

    pub fn switch(&self, name: &str) -> bool {
        self.switches.iter().any(|s| s == name)
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }
}

/// Render a usage block for `spec`.
pub fn usage(cmd: &str, about: &str, spec: &[ArgSpec]) -> String {
    let mut s = format!("{about}\n\nUSAGE: laq {cmd} [OPTIONS]\n\nOPTIONS:\n");
    for a in spec {
        let head = if a.is_switch {
            format!("  --{}", a.name)
        } else {
            format!("  --{} <v>", a.name)
        };
        let def = match a.default {
            Some(d) if !a.is_switch => format!(" [default: {d}]"),
            _ => String::new(),
        };
        s.push_str(&format!("{head:<26}{}{def}\n", a.help));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> Vec<ArgSpec> {
        vec![
            ArgSpec { name: "iters", help: "iterations", default: Some("100"), is_switch: false },
            ArgSpec { name: "alpha", help: "stepsize", default: None, is_switch: false },
            ArgSpec { name: "verbose", help: "chatty", default: None, is_switch: true },
        ]
    }

    fn sv(v: &[&str]) -> Vec<String> {
        v.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parse_space_and_equals_forms() {
        let a = Args::parse(&sv(&["--iters", "5", "--alpha=0.02"]), &spec()).unwrap();
        assert_eq!(a.get_usize("iters").unwrap(), Some(5));
        assert_eq!(a.get_f64("alpha").unwrap(), Some(0.02));
    }

    #[test]
    fn defaults_applied() {
        let a = Args::parse(&sv(&[]), &spec()).unwrap();
        assert_eq!(a.get_usize("iters").unwrap(), Some(100));
        assert_eq!(a.get("alpha"), None);
    }

    #[test]
    fn switches_and_positional() {
        let a = Args::parse(&sv(&["run", "--verbose", "x"]), &spec()).unwrap();
        assert!(a.switch("verbose"));
        assert_eq!(a.positional(), &["run".to_string(), "x".to_string()]);
    }

    #[test]
    fn unknown_flag_rejected() {
        assert_eq!(
            Args::parse(&sv(&["--nope"]), &spec()).unwrap_err(),
            CliError::UnknownFlag("nope".into())
        );
    }

    #[test]
    fn missing_value_rejected() {
        assert_eq!(
            Args::parse(&sv(&["--alpha"]), &spec()).unwrap_err(),
            CliError::MissingValue("alpha".into())
        );
    }

    #[test]
    fn invalid_number_reports_flag() {
        let a = Args::parse(&sv(&["--iters", "abc"]), &spec()).unwrap();
        match a.get_usize("iters").unwrap_err() {
            CliError::Invalid(name, _) => assert_eq!(name, "iters"),
            e => panic!("{e:?}"),
        }
    }

    #[test]
    fn usage_mentions_all_flags() {
        let u = usage("train", "Train a model", &spec());
        for f in ["--iters", "--alpha", "--verbose"] {
            assert!(u.contains(f), "{u}");
        }
    }
}
