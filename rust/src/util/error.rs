//! Library-wide error type.

pub type Result<T> = std::result::Result<T, Error>;

#[derive(thiserror::Error, Debug)]
pub enum Error {
    #[error("config error: {0}")]
    Config(String),

    #[error("data error: {0}")]
    Data(String),

    #[error("codec error: {0}")]
    Codec(String),

    #[error("runtime (PJRT) error: {0}")]
    Runtime(String),

    #[error("experiment error: {0}")]
    Experiment(String),

    #[error("io error: {0}")]
    Io(#[from] std::io::Error),

    #[error("json error: {0}")]
    Json(#[from] crate::util::json::ParseError),

    #[error("{0}")]
    Msg(String),
}

impl Error {
    pub fn msg(s: impl Into<String>) -> Self {
        Error::Msg(s.into())
    }
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Runtime(format!("{e:?}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_variants() {
        assert_eq!(Error::msg("x").to_string(), "x");
        assert!(Error::Config("bad".into()).to_string().contains("config"));
    }
}
