//! Library-wide error type (hand-rolled Display/Error impls — thiserror
//! is not vendored offline).

pub type Result<T> = std::result::Result<T, Error>;

#[derive(Debug)]
pub enum Error {
    Config(String),
    Data(String),
    Codec(String),
    Runtime(String),
    Experiment(String),
    Io(std::io::Error),
    Json(crate::util::json::ParseError),
    /// TCP transport protocol violation: malformed frame header, an
    /// oversized declared length, an out-of-order handshake, a peer that
    /// closed mid-frame.  Distinct from [`Error::Codec`] (payload-level
    /// damage inside a well-formed frame) and [`Error::Io`] (the socket
    /// itself failed).
    Transport(String),
    Msg(String),
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Error::Config(s) => write!(f, "config error: {s}"),
            Error::Data(s) => write!(f, "data error: {s}"),
            Error::Codec(s) => write!(f, "codec error: {s}"),
            Error::Runtime(s) => write!(f, "runtime (PJRT) error: {s}"),
            Error::Experiment(s) => write!(f, "experiment error: {s}"),
            Error::Io(e) => write!(f, "io error: {e}"),
            Error::Json(e) => write!(f, "json error: {e}"),
            Error::Transport(s) => write!(f, "transport error: {s}"),
            Error::Msg(s) => write!(f, "{s}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            Error::Json(e) => Some(e),
            _ => None,
        }
    }
}

impl Error {
    pub fn msg(s: impl Into<String>) -> Self {
        Error::Msg(s.into())
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

impl From<crate::util::json::ParseError> for Error {
    fn from(e: crate::util::json::ParseError) -> Self {
        Error::Json(e)
    }
}

impl From<crate::runtime::xla::Error> for Error {
    fn from(e: crate::runtime::xla::Error) -> Self {
        Error::Runtime(format!("{e:?}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_variants() {
        assert_eq!(Error::msg("x").to_string(), "x");
        assert!(Error::Config("bad".into()).to_string().contains("config"));
    }

    #[test]
    fn io_and_xla_conversions() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let e: Error = io.into();
        assert!(e.to_string().contains("io error"));
        let e: Error = crate::runtime::xla::Error("no pjrt".into()).into();
        assert!(e.to_string().contains("runtime"));
    }
}
