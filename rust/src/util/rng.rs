//! Deterministic pseudo-random number generation.
//!
//! crates.io `rand` is unavailable offline, so this module provides the
//! project's RNG substrate: SplitMix64 for seeding, Xoshiro256** as the
//! main generator, plus the distributions the data generators and the
//! stochastic algorithms need (uniform, normal, gamma/Dirichlet,
//! permutations, Bernoulli).  Everything is reproducible from a `u64`
//! seed, which the experiment configs record.

/// SplitMix64 — used to expand a user seed into Xoshiro state.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// Xoshiro256** — fast, high-quality, 2^256-1 period.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// cached second normal from Box–Muller
    spare_normal: Option<f64>,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let mut s = [0u64; 4];
        for v in s.iter_mut() {
            *v = sm.next_u64();
        }
        // avoid the all-zero state (probability ~0, but be exact)
        if s == [0, 0, 0, 0] {
            s[0] = 1;
        }
        Self { s, spare_normal: None }
    }

    /// Derive an independent child generator (for per-worker streams).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    /// Counter-based stream: a generator that is a pure function of
    /// `(seed, stream_id, counter)`.  Unlike [`Rng::fork`] this needs no
    /// parent generator state, so parallel consumers (one stream per
    /// worker per iteration in the trainer's local phase) get identical
    /// draws no matter which thread runs them or in what order — the
    /// property `rust/tests/parallel_equivalence.rs` pins down.
    pub fn stream(seed: u64, stream_id: u64, counter: u64) -> Rng {
        let key = seed
            ^ 0xA076_1D64_78BD_642F
            ^ stream_id.wrapping_mul(0x9E37_79B9_7F4A_7C15)
            ^ counter
                .wrapping_mul(0xD6E8_FEB8_6659_FD93)
                .rotate_left(17);
        // Rng::new runs the key through SplitMix64, decorrelating
        // neighbouring (stream_id, counter) pairs
        Rng::new(key)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        // 53 high bits -> [0,1) double
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform_range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.uniform() < p
    }

    /// Standard normal via Box–Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        loop {
            let u1 = self.uniform();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
            self.spare_normal = Some(r * s);
            return r * c;
        }
    }

    #[inline]
    pub fn normal_scaled(&mut self, mu: f64, sigma: f64) -> f64 {
        mu + sigma * self.normal()
    }

    /// Gamma(alpha, 1) via Marsaglia–Tsang; alpha > 0.
    pub fn gamma(&mut self, alpha: f64) -> f64 {
        if alpha < 1.0 {
            // boost: Gamma(a) = Gamma(a+1) * U^(1/a)
            let g = self.gamma(alpha + 1.0);
            let u: f64 = self.uniform().max(f64::MIN_POSITIVE);
            return g * u.powf(1.0 / alpha);
        }
        let d = alpha - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = (1.0 + c * x).powi(3);
            if v <= 0.0 {
                continue;
            }
            let u = self.uniform();
            if u < 1.0 - 0.0331 * x.powi(4) {
                return d * v;
            }
            if u.ln() < 0.5 * x * x + d * (1.0 - v + v.ln()) {
                return d * v;
            }
        }
    }

    /// Dirichlet(alpha * 1_k) sample of length k.
    pub fn dirichlet(&mut self, alpha: f64, k: usize) -> Vec<f64> {
        let mut v: Vec<f64> = (0..k).map(|_| self.gamma(alpha)).collect();
        let s: f64 = v.iter().sum();
        if s <= 0.0 {
            return vec![1.0 / k as f64; k];
        }
        for x in v.iter_mut() {
            *x /= s;
        }
        v
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            v.swap(i, j);
        }
    }

    /// Random permutation of 0..n.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut v: Vec<usize> = (0..n).collect();
        self.shuffle(&mut v);
        v
    }

    /// Sample `k` distinct indices from 0..n (k <= n), unordered.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        // partial Fisher–Yates
        let mut v: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below((n - i) as u64) as usize;
            v.swap(i, j);
        }
        v.truncate(k);
        v
    }

    /// Fill a slice with N(0, sigma^2) f32 values.
    pub fn fill_normal_f32(&mut self, out: &mut [f32], sigma: f32) {
        for x in out.iter_mut() {
            *x = (self.normal() * sigma as f64) as f32;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn uniform_in_unit_interval_and_roughly_uniform() {
        let mut r = Rng::new(7);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut r = Rng::new(3);
        let mut counts = [0usize; 5];
        for _ in 0..50_000 {
            counts[r.below(5) as usize] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 500.0, "{counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(9);
        let n = 50_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let z = r.normal();
            s1 += z;
            s2 += z * z;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn gamma_mean_matches_alpha() {
        let mut r = Rng::new(11);
        for &alpha in &[0.3, 1.0, 4.5] {
            let n = 20_000;
            let mean: f64 =
                (0..n).map(|_| r.gamma(alpha)).sum::<f64>() / n as f64;
            assert!(
                (mean - alpha).abs() < 0.1 * alpha.max(0.5),
                "alpha={alpha} mean={mean}"
            );
        }
    }

    #[test]
    fn dirichlet_sums_to_one() {
        let mut r = Rng::new(13);
        let v = r.dirichlet(0.5, 10);
        assert_eq!(v.len(), 10);
        assert!((v.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        assert!(v.iter().all(|&x| x >= 0.0));
    }

    #[test]
    fn permutation_is_a_permutation() {
        let mut r = Rng::new(17);
        let p = r.permutation(100);
        let mut seen = vec![false; 100];
        for &i in &p {
            assert!(!seen[i]);
            seen[i] = true;
        }
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(19);
        let s = r.sample_indices(50, 20);
        assert_eq!(s.len(), 20);
        let mut sorted = s.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 20);
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut root = Rng::new(5);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn counter_streams_are_deterministic_and_distinct() {
        // pure function of the key triple
        assert_eq!(
            Rng::stream(7, 3, 11).next_u64(),
            Rng::stream(7, 3, 11).next_u64()
        );
        // distinct along every axis
        let base: Vec<u64> = (0..4).map(|_| Rng::stream(7, 3, 11).next_u64()).collect();
        assert_ne!(base[0], Rng::stream(8, 3, 11).next_u64());
        assert_ne!(base[0], Rng::stream(7, 4, 11).next_u64());
        assert_ne!(base[0], Rng::stream(7, 3, 12).next_u64());
        // neighbouring workers/iterations decorrelate (spot-check means)
        let mut sum = 0.0;
        for m in 0..20u64 {
            for k in 0..20u64 {
                sum += Rng::stream(1, m, k).uniform();
            }
        }
        let mean = sum / 400.0;
        assert!((mean - 0.5).abs() < 0.08, "mean={mean}");
    }
}
