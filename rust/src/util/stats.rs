//! Summary statistics for the bench harness and metrics sinks.

/// Online mean/variance (Welford) plus min/max.
#[derive(Clone, Debug, Default)]
pub struct Running {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Running {
    pub fn new() -> Self {
        Self { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Percentile over a sample set (nearest-rank on a sorted copy).
pub fn percentile(samples: &[f64], p: f64) -> f64 {
    assert!(!samples.is_empty());
    assert!((0.0..=100.0).contains(&p));
    let mut v: Vec<f64> = samples.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = ((p / 100.0) * (v.len() as f64 - 1.0)).round() as usize;
    v[rank.min(v.len() - 1)]
}

/// Summary of a bench sample set.
#[derive(Clone, Debug)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
    pub max: f64,
}

impl Summary {
    pub fn from_samples(samples: &[f64]) -> Self {
        let mut r = Running::new();
        for &s in samples {
            r.push(s);
        }
        Self {
            n: samples.len(),
            mean: r.mean(),
            std: r.std(),
            min: r.min(),
            p50: percentile(samples, 50.0),
            p90: percentile(samples, 90.0),
            p99: percentile(samples, 99.0),
            max: r.max(),
        }
    }
}

/// Least-squares slope of log10(y) vs x — used to verify *linear rate*
/// claims: a convergence trace y_k = C σ^k has log-slope log10(σ) < 0.
pub fn log_slope(y: &[f64]) -> f64 {
    let pts: Vec<(f64, f64)> = y
        .iter()
        .enumerate()
        .filter(|(_, &v)| v > 0.0 && v.is_finite())
        .map(|(i, &v)| (i as f64, v.log10()))
        .collect();
    if pts.len() < 2 {
        return 0.0;
    }
    let n = pts.len() as f64;
    let sx: f64 = pts.iter().map(|p| p.0).sum();
    let sy: f64 = pts.iter().map(|p| p.1).sum();
    let sxx: f64 = pts.iter().map(|p| p.0 * p.0).sum();
    let sxy: f64 = pts.iter().map(|p| p.0 * p.1).sum();
    (n * sxy - sx * sy) / (n * sxx - sx * sx)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn running_mean_var() {
        let mut r = Running::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            r.push(x);
        }
        assert!((r.mean() - 5.0).abs() < 1e-12);
        assert!((r.var() - 32.0 / 7.0).abs() < 1e-12);
        assert_eq!(r.min(), 2.0);
        assert_eq!(r.max(), 9.0);
        assert_eq!(r.count(), 8);
    }

    #[test]
    fn percentiles() {
        let v: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&v, 100.0), 100.0);
        let p50 = percentile(&v, 50.0);
        assert!((p50 - 50.0).abs() <= 1.0);
    }

    #[test]
    fn summary_sane() {
        let s = Summary::from_samples(&[1.0, 2.0, 3.0]);
        assert_eq!(s.n, 3);
        assert!((s.mean - 2.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 3.0);
    }

    #[test]
    fn log_slope_detects_linear_rate() {
        // y_k = 10 * 0.9^k  => slope = log10(0.9) ≈ -0.0458
        let y: Vec<f64> = (0..50).map(|k| 10.0 * 0.9f64.powi(k)).collect();
        let s = log_slope(&y);
        assert!((s - 0.9f64.log10()).abs() < 1e-9);
        // sublinear (1/k) has slope tending to 0: flatter than geometric
        let y2: Vec<f64> = (1..=50).map(|k| 1.0 / k as f64).collect();
        assert!(log_slope(&y2) > s);
    }

    #[test]
    fn log_slope_ignores_nonpositive() {
        let y = [1.0, 0.0, 0.1, -3.0, 0.01];
        assert!(log_slope(&y).is_finite());
    }
}
