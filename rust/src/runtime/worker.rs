//! [`WorkerGrad`] implementation over the PJRT runtime: each worker's
//! gradient evaluation is one `call()` into the AOT-compiled `*_grad`
//! artifact (L2 jax graph containing the L1 Pallas kernels).

use std::sync::Arc;

use super::{Runtime, Value};
use crate::data::Dataset;
use crate::model::WorkerGrad;
use crate::{Error, Result};

/// PJRT-backed per-worker gradient oracle for the supervised models
/// (logreg / mlp): artifacts with signature
/// `(theta f32[p], x f32[n,f], y i32[n]) -> (loss f32[], grad f32[p])`.
pub struct PjrtGradWorker {
    rt: Arc<Runtime>,
    /// artifact evaluating the full shard (e.g. "logreg_grad")
    art_full: String,
    /// artifact evaluating one minibatch (e.g. "logreg_grad_batch")
    art_batch: Option<String>,
    shard: Dataset,
    dim: usize,
    batch_rows: usize,
    /// cached flat shard tensors (built once; the shard never changes)
    x_value: Value,
    y_value: Value,
}

impl PjrtGradWorker {
    pub fn new(
        rt: Arc<Runtime>,
        art_full: &str,
        art_batch: Option<&str>,
        shard: Dataset,
    ) -> Result<Self> {
        let sig = rt.signature(art_full)?;
        if sig.inputs.len() != 3 || sig.outputs.len() != 2 {
            return Err(Error::Runtime(format!(
                "'{art_full}' is not a grad artifact (needs 3 inputs / 2 outputs)"
            )));
        }
        let dim = sig.inputs[0].elements();
        if sig.outputs[1].elements() != dim {
            return Err(Error::Runtime("grad output dim != theta dim".into()));
        }
        let n_exp = sig.inputs[2].elements();
        if shard.n != n_exp {
            return Err(Error::Runtime(format!(
                "'{art_full}' expects shard of {n_exp} rows, got {}",
                shard.n
            )));
        }
        let batch_rows = match art_batch {
            Some(b) => rt.signature(b)?.inputs[2].elements(),
            None => 0,
        };
        let x_value = Value::F32(shard.x.to_vec());
        let y_value = Value::I32(shard.y.iter().map(|&v| v as i32).collect());
        Ok(Self {
            rt,
            art_full: art_full.to_string(),
            art_batch: art_batch.map(|s| s.to_string()),
            shard,
            dim,
            batch_rows,
            x_value,
            y_value,
        })
    }

    fn unpack(&self, out: Vec<Value>) -> Result<(f64, Vec<f32>)> {
        let loss = out[0].scalar_f32()? as f64;
        let grad = out[1].as_f32()?.to_vec();
        Ok((loss, grad))
    }
}

impl WorkerGrad for PjrtGradWorker {
    fn dim(&self) -> usize {
        self.dim
    }

    fn full(&mut self, theta: &[f32]) -> Result<(f64, Vec<f32>)> {
        let out = self.rt.call(
            &self.art_full,
            &[
                Value::F32(theta.to_vec()),
                self.x_value.clone(),
                self.y_value.clone(),
            ],
        )?;
        self.unpack(out)
    }

    fn batch(&mut self, theta: &[f32], rows: &[usize]) -> Result<(f64, Vec<f32>)> {
        let art = self.art_batch.as_ref().ok_or_else(|| {
            Error::Runtime(format!("'{}' has no batch artifact", self.art_full))
        })?;
        if rows.len() != self.batch_rows {
            return Err(Error::Runtime(format!(
                "batch artifact expects {} rows, got {}",
                self.batch_rows,
                rows.len()
            )));
        }
        let f = self.shard.features;
        let mut xb = Vec::with_capacity(rows.len() * f);
        let mut yb = Vec::with_capacity(rows.len());
        for &i in rows {
            xb.extend_from_slice(self.shard.row(i));
            yb.push(self.shard.y[i] as i32);
        }
        let out = self.rt.call(
            art,
            &[Value::F32(theta.to_vec()), Value::F32(xb), Value::I32(yb)],
        )?;
        self.unpack(out)
    }

    fn shard_len(&self) -> usize {
        self.shard.n
    }
}

/// PJRT-backed worker for the transformer LM: artifact signature
/// `(flat f32[p], tokens i32[b,t]) -> (loss, grad)`.  The "shard" is a
/// pool of token sequences; `full` evaluates a fixed deterministic batch,
/// `batch` selects sequences by index.
pub struct PjrtTfmWorker {
    rt: Arc<Runtime>,
    art: String,
    /// pool of sequences, each `seq_len` long
    pool: Vec<Vec<i32>>,
    dim: usize,
    batch_seqs: usize,
    seq_len: usize,
}

impl PjrtTfmWorker {
    pub fn new(rt: Arc<Runtime>, art: &str, pool: Vec<Vec<i32>>) -> Result<Self> {
        let sig = rt.signature(art)?;
        if sig.inputs.len() != 2 || sig.outputs.len() != 2 {
            return Err(Error::Runtime(format!("'{art}' is not a tfm grad artifact")));
        }
        let dim = sig.inputs[0].elements();
        let (batch_seqs, seq_len) = match sig.inputs[1].shape.as_slice() {
            [b, t] => (*b, *t),
            _ => return Err(Error::Runtime("tokens input must be rank 2".into())),
        };
        if pool.len() < batch_seqs {
            return Err(Error::Runtime(format!(
                "pool of {} sequences < batch {batch_seqs}",
                pool.len()
            )));
        }
        if let Some(bad) = pool.iter().find(|s| s.len() != seq_len) {
            return Err(Error::Runtime(format!(
                "sequence of length {} != seq_len {seq_len}",
                bad.len()
            )));
        }
        Ok(Self { rt, art: art.to_string(), pool, dim, batch_seqs, seq_len })
    }

    pub fn batch_seqs(&self) -> usize {
        self.batch_seqs
    }

    fn eval(&self, theta: &[f32], seq_idx: &[usize]) -> Result<(f64, Vec<f32>)> {
        let mut toks = Vec::with_capacity(self.batch_seqs * self.seq_len);
        for &i in seq_idx {
            toks.extend_from_slice(&self.pool[i]);
        }
        let out = self
            .rt
            .call(&self.art, &[Value::F32(theta.to_vec()), Value::I32(toks)])?;
        Ok((out[0].scalar_f32()? as f64, out[1].as_f32()?.to_vec()))
    }
}

impl WorkerGrad for PjrtTfmWorker {
    fn dim(&self) -> usize {
        self.dim
    }

    fn full(&mut self, theta: &[f32]) -> Result<(f64, Vec<f32>)> {
        let idx: Vec<usize> = (0..self.batch_seqs).collect();
        self.eval(theta, &idx)
    }

    fn batch(&mut self, theta: &[f32], rows: &[usize]) -> Result<(f64, Vec<f32>)> {
        if rows.len() != self.batch_seqs {
            return Err(Error::Runtime(format!(
                "tfm batch needs exactly {} sequences",
                self.batch_seqs
            )));
        }
        self.eval(theta, rows)
    }

    fn shard_len(&self) -> usize {
        self.pool.len()
    }
}
