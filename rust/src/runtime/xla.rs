//! Offline stand-in for the vendored `xla` crate (PJRT bindings).
//!
//! The CI container for this repo has no crates.io access and no vendored
//! `xla` source tree, so the real bindings cannot be linked.  This module
//! mirrors the exact API surface `runtime/mod.rs` consumes — literals,
//! client, HLO-text loading, compile, execute — with host-side types that
//! compile everywhere.  [`PjRtClient::cpu`] fails with a clear message, so
//! every PJRT-dependent path degrades the same way a missing `artifacts/`
//! directory does: `Runtime::open` returns an error and the runtime tests
//! print a SKIP notice.  Swapping the real crate back in means deleting
//! `pub mod xla;` in `runtime/mod.rs` and adding the vendored path
//! dependency — call sites are API-compatible, **but** see the Send note
//! below: the swap is not free.
//!
//! The stub types are plain owned data (no raw PJRT handles), so they are
//! `Send + Sync` and the `Arc<Runtime>` sharing used by the parallel
//! worker fan-out is sound.  The real bindings hold raw C++ pointers and
//! are **not** `Send`, while `WorkerGrad` (and therefore
//! `PjrtGradWorker`) now requires `Send` for the trainer's fan-out.  A
//! build against the vendored crate must additionally pick a strategy:
//! either `unsafe impl Send + Sync for Runtime` justified by the `Mutex`
//! around the executable cache plus PJRT's own thread-safe execution
//! contract, or keep PJRT-backed trainers on `threads = 1` (the
//! sequential path never moves a node across threads).

/// Error type matching `xla::Error`'s role (converted into
/// [`crate::Error::Runtime`] at the boundary).
#[derive(Debug, Clone, PartialEq)]
pub struct Error(pub String);

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "xla: {}", self.0)
    }
}

impl std::error::Error for Error {}

pub type XlaResult<T> = std::result::Result<T, Error>;

fn unavailable<T>() -> XlaResult<T> {
    Err(Error(
        "PJRT runtime unavailable: this build uses the offline xla stub \
         (src/runtime/xla.rs); link the vendored xla crate to execute AOT \
         artifacts"
            .into(),
    ))
}

/// Element types a rank-1 literal can hold (f32 / i32 are the only dtypes
/// crossing the PJRT boundary in this project).
pub trait LiteralElement: Copy {
    fn wrap(v: &[Self]) -> Literal;
    fn unwrap(lit: &Literal) -> XlaResult<Vec<Self>>;
}

impl LiteralElement for f32 {
    fn wrap(v: &[Self]) -> Literal {
        Literal::F32(v.to_vec())
    }
    fn unwrap(lit: &Literal) -> XlaResult<Vec<Self>> {
        match lit {
            Literal::F32(v) => Ok(v.clone()),
            _ => Err(Error("literal is not f32".into())),
        }
    }
}

impl LiteralElement for i32 {
    fn wrap(v: &[Self]) -> Literal {
        Literal::I32(v.to_vec())
    }
    fn unwrap(lit: &Literal) -> XlaResult<Vec<Self>> {
        match lit {
            Literal::I32(v) => Ok(v.clone()),
            _ => Err(Error("literal is not i32".into())),
        }
    }
}

/// Host-side literal (flat storage; shape is carried by the manifest).
#[derive(Debug, Clone, PartialEq)]
pub enum Literal {
    F32(Vec<f32>),
    I32(Vec<i32>),
    Tuple(Vec<Literal>),
}

impl Literal {
    /// Rank-1 literal over a host slice.
    pub fn vec1<T: LiteralElement>(v: &[T]) -> Literal {
        T::wrap(v)
    }

    /// Reshape is a no-op on the stub's flat storage (the manifest is the
    /// source of shape truth; `Runtime::call` validates element counts).
    pub fn reshape(self, _dims: &[i64]) -> XlaResult<Literal> {
        Ok(self)
    }

    pub fn to_vec<T: LiteralElement>(&self) -> XlaResult<Vec<T>> {
        T::unwrap(self)
    }

    pub fn to_tuple(self) -> XlaResult<Vec<Literal>> {
        match self {
            Literal::Tuple(v) => Ok(v),
            other => Ok(vec![other]),
        }
    }
}

/// Parsed HLO module (the stub never parses; loading fails first).
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> XlaResult<Self> {
        unavailable()
    }
}

/// Compilable computation.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// Device buffer handle returned by an execution.
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> XlaResult<Literal> {
        unavailable()
    }
}

/// Compiled executable.
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    /// Mirrors `xla::PjRtLoadedExecutable::execute` (replica-major result).
    pub fn execute<T>(&self, _args: &[Literal]) -> XlaResult<Vec<Vec<PjRtBuffer>>> {
        unavailable()
    }
}

/// PJRT client handle.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> XlaResult<Self> {
        unavailable()
    }

    pub fn platform_name(&self) -> String {
        "stub".into()
    }

    pub fn device_count(&self) -> usize {
        0
    }

    pub fn compile(&self, _comp: &XlaComputation) -> XlaResult<PjRtLoadedExecutable> {
        unavailable()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        let err = PjRtClient::cpu().err().expect("stub client must fail");
        assert!(err.to_string().contains("stub"));
    }

    #[test]
    fn literal_roundtrips_host_data() {
        let l = Literal::vec1(&[1.0f32, 2.0]);
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1.0, 2.0]);
        assert!(l.to_vec::<i32>().is_err());
        let l = Literal::vec1(&[3i32]).reshape(&[1, 1]).unwrap();
        assert_eq!(l.to_vec::<i32>().unwrap(), vec![3]);
        let t = Literal::Tuple(vec![Literal::F32(vec![1.0])]);
        assert_eq!(t.to_tuple().unwrap().len(), 1);
    }
}
