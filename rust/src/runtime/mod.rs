//! PJRT runtime: load + execute the AOT HLO artifacts.
//!
//! `make artifacts` (python, build-time only) lowers the L2 jax graphs —
//! which call the L1 Pallas kernels — to HLO **text** and writes
//! `artifacts/manifest.json` describing every artifact's I/O signature.
//! This module is the request-path half: parse the manifest, compile each
//! HLO module once on the PJRT CPU client (`xla` crate 0.1.6), and execute
//! with zero python anywhere in the process.
//!
//! Interchange is HLO text, not serialized protos: jax ≥ 0.5 emits 64-bit
//! instruction ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids (see /opt/xla-example/README.md and DESIGN.md §2).
//!
//! Offline builds use the in-tree [`xla`] stub module instead of the
//! vendored crate; [`Runtime::open`] then fails cleanly and every caller
//! (examples, the `pjrt` backend, the artifact tests) already treats that
//! as "artifacts not available" and skips.  Re-linking the real crate
//! additionally needs a `Send` strategy for the raw PJRT handles — see
//! the note at the top of [`xla`].

pub mod worker;
pub mod xla;

pub use worker::PjrtGradWorker;

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use crate::util::json::Json;
use crate::{Error, Result};

/// Element type crossing the PJRT boundary.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    fn parse(s: &str) -> Result<DType> {
        match s {
            "f32" => Ok(DType::F32),
            "i32" => Ok(DType::I32),
            other => Err(Error::Runtime(format!("unsupported dtype '{other}'"))),
        }
    }
}

/// Shape + dtype of one artifact input/output.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorSig {
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl TensorSig {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(j: &Json) -> Result<TensorSig> {
        let shape = j
            .get("shape")
            .as_arr()
            .ok_or_else(|| Error::Runtime("signature missing shape".into()))?
            .iter()
            .map(|v| v.as_usize().ok_or_else(|| Error::Runtime("bad dim".into())))
            .collect::<Result<Vec<_>>>()?;
        let dtype = DType::parse(
            j.get("dtype")
                .as_str()
                .ok_or_else(|| Error::Runtime("signature missing dtype".into()))?,
        )?;
        Ok(TensorSig { shape, dtype })
    }
}

/// One manifest entry.
#[derive(Clone, Debug)]
pub struct ArtifactSig {
    pub name: String,
    pub file: String,
    pub inputs: Vec<TensorSig>,
    pub outputs: Vec<TensorSig>,
    pub meta: Json,
}

/// Host-side tensor argument / result.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl Value {
    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            Value::F32(v) => Ok(v),
            _ => Err(Error::Runtime("expected f32 value".into())),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            Value::I32(v) => Ok(v),
            _ => Err(Error::Runtime("expected i32 value".into())),
        }
    }

    pub fn scalar_f32(&self) -> Result<f32> {
        let v = self.as_f32()?;
        if v.len() != 1 {
            return Err(Error::Runtime(format!("expected scalar, got {} elems", v.len())));
        }
        Ok(v[0])
    }

    fn len(&self) -> usize {
        match self {
            Value::F32(v) => v.len(),
            Value::I32(v) => v.len(),
        }
    }

    fn dtype(&self) -> DType {
        match self {
            Value::F32(_) => DType::F32,
            Value::I32(_) => DType::I32,
        }
    }

    fn to_literal(&self, sig: &TensorSig) -> Result<xla::Literal> {
        let dims: Vec<i64> = sig.shape.iter().map(|&d| d as i64).collect();
        let lit = match self {
            Value::F32(v) => xla::Literal::vec1(v),
            Value::I32(v) => xla::Literal::vec1(v),
        };
        if sig.shape.len() == 1 && sig.shape[0] == self.len() {
            return Ok(lit);
        }
        Ok(lit.reshape(&dims)?)
    }
}

/// The PJRT session: client + manifest + compile-on-demand executable cache.
///
/// Shared as `Arc<Runtime>` so PJRT-backed workers can ride the trainer's
/// parallel local phase; the executable cache is mutex-guarded and
/// `call()` takes `&self`, so concurrent gradient evaluations serialize
/// only on cache misses (compilation), never on execution dispatch.  The
/// real PJRT handles are raw pointers owned by one process-wide client;
/// the in-tree [`xla`] stub's types are plain host data, and a build
/// against the vendored bindings must keep this mutex discipline.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    artifacts: HashMap<String, ArtifactSig>,
    exes: Mutex<HashMap<String, Arc<xla::PjRtLoadedExecutable>>>,
}

impl Runtime {
    /// Open `artifacts/` (reads `manifest.json`, creates the CPU client).
    pub fn open(dir: impl AsRef<Path>) -> Result<Arc<Runtime>> {
        let dir = dir.as_ref().to_path_buf();
        let man_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&man_path).map_err(|e| {
            Error::Runtime(format!(
                "cannot read {} (run `make artifacts` first): {e}",
                man_path.display()
            ))
        })?;
        let doc = Json::parse(&text)?;
        let mut artifacts = HashMap::new();
        for a in doc
            .get("artifacts")
            .as_arr()
            .ok_or_else(|| Error::Runtime("manifest missing 'artifacts'".into()))?
        {
            let name = a
                .get("name")
                .as_str()
                .ok_or_else(|| Error::Runtime("artifact missing name".into()))?
                .to_string();
            let sig = ArtifactSig {
                name: name.clone(),
                file: a
                    .get("file")
                    .as_str()
                    .ok_or_else(|| Error::Runtime("artifact missing file".into()))?
                    .to_string(),
                inputs: a
                    .get("inputs")
                    .as_arr()
                    .unwrap_or(&[])
                    .iter()
                    .map(TensorSig::from_json)
                    .collect::<Result<Vec<_>>>()?,
                outputs: a
                    .get("outputs")
                    .as_arr()
                    .unwrap_or(&[])
                    .iter()
                    .map(TensorSig::from_json)
                    .collect::<Result<Vec<_>>>()?,
                meta: a.get("meta").clone(),
            };
            artifacts.insert(name, sig);
        }
        let client = xla::PjRtClient::cpu()?;
        crate::log_info!(
            "runtime: PJRT platform={} devices={} artifacts={}",
            client.platform_name(),
            client.device_count(),
            artifacts.len()
        );
        Ok(Arc::new(Runtime {
            client,
            dir,
            artifacts,
            exes: Mutex::new(HashMap::new()),
        }))
    }

    pub fn signature(&self, name: &str) -> Result<&ArtifactSig> {
        self.artifacts
            .get(name)
            .ok_or_else(|| Error::Runtime(format!("unknown artifact '{name}'")))
    }

    pub fn artifact_names(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.artifacts.keys().map(|s| s.as_str()).collect();
        v.sort_unstable();
        v
    }

    /// Compile (or fetch cached) executable for `name`.
    fn executable(&self, name: &str) -> Result<Arc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self.exes.lock().unwrap().get(name) {
            return Ok(Arc::clone(e));
        }
        let sig = self.signature(name)?;
        let path = self.dir.join(&sig.file);
        let t0 = std::time::Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str()
                .ok_or_else(|| Error::Runtime("non-utf8 artifact path".into()))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Arc::new(self.client.compile(&comp)?);
        crate::log_info!("runtime: compiled '{name}' in {:.1?}", t0.elapsed());
        self.exes
            .lock()
            .unwrap()
            .insert(name.to_string(), Arc::clone(&exe));
        Ok(exe)
    }

    /// Pre-compile a set of artifacts (startup cost off the hot path).
    pub fn warmup(&self, names: &[&str]) -> Result<()> {
        for n in names {
            self.executable(n)?;
        }
        Ok(())
    }

    /// Execute artifact `name` with `args`, returning one [`Value`] per
    /// manifest output.  Shapes and dtypes are validated against the
    /// manifest before touching PJRT.
    pub fn call(&self, name: &str, args: &[Value]) -> Result<Vec<Value>> {
        let sig = self.signature(name)?.clone();
        if args.len() != sig.inputs.len() {
            return Err(Error::Runtime(format!(
                "'{name}' expects {} inputs, got {}",
                sig.inputs.len(),
                args.len()
            )));
        }
        let mut literals = Vec::with_capacity(args.len());
        for (i, (a, s)) in args.iter().zip(&sig.inputs).enumerate() {
            if a.len() != s.elements() || a.dtype() != s.dtype {
                return Err(Error::Runtime(format!(
                    "'{name}' input {i}: expected {:?}{:?} ({} elems), got {:?} ({} elems)",
                    s.dtype,
                    s.shape,
                    s.elements(),
                    a.dtype(),
                    a.len()
                )));
            }
            literals.push(a.to_literal(s)?);
        }
        let exe = self.executable(name)?;
        let result = exe.execute::<xla::Literal>(&literals)?;
        let out = result[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True: always a tuple literal
        let parts = out.to_tuple()?;
        if parts.len() != sig.outputs.len() {
            return Err(Error::Runtime(format!(
                "'{name}' returned {} outputs, manifest says {}",
                parts.len(),
                sig.outputs.len()
            )));
        }
        parts
            .into_iter()
            .zip(&sig.outputs)
            .map(|(lit, s)| {
                let v = match s.dtype {
                    DType::F32 => Value::F32(lit.to_vec::<f32>()?),
                    DType::I32 => Value::I32(lit.to_vec::<i32>()?),
                };
                if v.len() != s.elements() {
                    return Err(Error::Runtime(format!(
                        "'{name}' output length {} != manifest {}",
                        v.len(),
                        s.elements()
                    )));
                }
                Ok(v)
            })
            .collect()
    }

    /// Convenience: innovation quantization through the `quantize_*`
    /// artifact — used by tests to prove the rust codec and the L1 Pallas
    /// kernel agree bit-for-bit on the artifact path.
    pub fn quantize_via_artifact(
        &self,
        name: &str,
        g: &[f32],
        q_prev: &[f32],
    ) -> Result<(f32, Vec<u32>, Vec<f32>)> {
        let out = self.call(
            name,
            &[Value::F32(g.to_vec()), Value::F32(q_prev.to_vec())],
        )?;
        let r = out[0].scalar_f32()?;
        let codes = out[1].as_f32()?.iter().map(|&c| c as u32).collect();
        let deq = out[2].as_f32()?.to_vec();
        Ok((r, codes, deq))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Full runtime tests (needing built artifacts) live in
    // rust/tests/runtime_artifacts.rs; here we test the manifest parsing
    // and validation logic without touching PJRT.

    #[test]
    fn tensor_sig_from_json() {
        let j = Json::parse(r#"{"shape": [3, 4], "dtype": "f32"}"#).unwrap();
        let s = TensorSig::from_json(&j).unwrap();
        assert_eq!(s.shape, vec![3, 4]);
        assert_eq!(s.dtype, DType::F32);
        assert_eq!(s.elements(), 12);
        let bad = Json::parse(r#"{"shape": [3], "dtype": "f64"}"#).unwrap();
        assert!(TensorSig::from_json(&bad).is_err());
    }

    #[test]
    fn value_accessors() {
        let v = Value::F32(vec![1.5]);
        assert_eq!(v.scalar_f32().unwrap(), 1.5);
        assert!(v.as_i32().is_err());
        let w = Value::I32(vec![1, 2]);
        assert_eq!(w.as_i32().unwrap(), &[1, 2]);
        assert!(w.scalar_f32().is_err());
        assert_eq!(w.len(), 2);
        assert_eq!(w.dtype(), DType::I32);
    }

    #[test]
    fn scalar_requires_len_1() {
        assert!(Value::F32(vec![1.0, 2.0]).scalar_f32().is_err());
    }
}
