//! `cargo bench` entry — self-contained harness (criterion is not
//! vendored offline).  Three parts:
//!
//! 1. **Hot-path micro-benchmarks** (codec pack/unpack, criterion
//!    evaluation, sharded server absorb/apply, full trainer step per
//!    algorithm) with warmup + sampled timing (mean/p50/p99) — the §Perf
//!    numbers in EXPERIMENTS.md come from here.
//! 2. **One end-to-end bench per paper table/figure** at reduced scale —
//!    regenerates each comparison's rows (who wins, by what factor) and
//!    reports the wall time of the sweep.
//! 3. **Machine-readable output** — every sampled group also lands in
//!    `BENCH_server.json` (server-side groups) and `BENCH_trainer.json`
//!    (end-to-end step throughput, sync vs async wire phase over
//!    M × p sweeps) with p50/p99/mean per bench and the host core count,
//!    so CI can track the perf trajectory.
//!
//! Output is plain text; `cargo bench 2>&1 | tee bench_output.txt`.
//! Set `LAQ_BENCH_QUICK=1` for the CI smoke mode: only the sharded-server,
//! trainer-wire, dial-a-bit, scenario, and resilience groups run (reduced
//! sampling) and both JSONs are still emitted.

use laq::algo::{build_native, Trainer};
use laq::comm::{LatencyModel, Payload};
use laq::config::{Algo, BitScheduleKind, DownlinkMode, ModelKind, RunCfg, WireMode};
use laq::coordinator::worker::{LazyCodec, WorkerNode};
use laq::coordinator::ServerState;
use laq::experiments::{self, ExpOpts};
use laq::model::WorkerGrad;
use laq::quant::qsgd::QsgdQuantizer;
use laq::quant::sparsify::Sparsifier;
use laq::quant::{InnovationQuantizer, QuantizedInnovation};
use laq::util::json::Json;
use laq::util::rng::Rng;
use laq::util::stats::Summary;
use std::hint::black_box;
use std::time::Instant;

/// Time `f` with warmup; returns per-iteration seconds samples.
fn sample<F: FnMut()>(mut f: F, warmup: usize, samples: usize, iters_per: usize) -> Vec<f64> {
    for _ in 0..warmup {
        f();
    }
    (0..samples)
        .map(|_| {
            let t0 = Instant::now();
            for _ in 0..iters_per {
                f();
            }
            t0.elapsed().as_secs_f64() / iters_per as f64
        })
        .collect()
}

fn fmt_time(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1} ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2} µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2} ms", s * 1e3)
    } else {
        format!("{s:.3} s")
    }
}

fn report(name: &str, samples: &[f64], bytes_per_op: Option<usize>) -> Summary {
    let s = Summary::from_samples(samples);
    let tput = bytes_per_op
        .map(|b| format!("  {:.2} GB/s", b as f64 / s.p50 / 1e9))
        .unwrap_or_default();
    println!(
        "{name:<44} p50 {:>10}  mean {:>10}  p99 {:>10}{tput}",
        fmt_time(s.p50),
        fmt_time(s.mean),
        fmt_time(s.p99)
    );
    s
}

/// One machine-readable bench record for BENCH_server.json.
fn json_entry(
    group: &str,
    bench: &str,
    p: usize,
    shards: usize,
    threads: usize,
    s: &Summary,
) -> Json {
    Json::obj(vec![
        ("group", Json::Str(group.into())),
        ("bench", Json::Str(bench.into())),
        ("p", Json::Num(p as f64)),
        ("shards", Json::Num(shards as f64)),
        ("threads", Json::Num(threads as f64)),
        ("p50_s", Json::Num(s.p50)),
        ("p99_s", Json::Num(s.p99)),
        ("mean_s", Json::Num(s.mean)),
    ])
}

fn bench_codecs() {
    println!("\n== L3 hot path: codecs (p = 7840, the logreg parameter dim) ==");
    let p = 7840;
    let mut rng = Rng::new(1);
    let g: Vec<f32> = (0..p).map(|_| rng.normal() as f32).collect();
    let qp: Vec<f32> = (0..p).map(|_| rng.normal() as f32).collect();
    let mut q_new = vec![0.0f32; p];
    let mut codes = Vec::with_capacity(p);

    for bits in [3u32, 8] {
        let q = InnovationQuantizer::new(bits);
        let s = sample(
            || {
                black_box(q.quantize_into(
                    black_box(&g),
                    black_box(&qp),
                    &mut codes,
                    &mut q_new,
                ));
            },
            20,
            30,
            20,
        );
        report(&format!("innovation quantize (b={bits})"), &s, Some(p * 4));

        let (qi, _) = q.quantize(&g, &qp);
        let mut w = laq::util::bitio::BitWriter::with_capacity_bits(qi.wire_bits());
        let s = sample(|| { qi.encode_into(&mut w); black_box(w.as_bytes()); }, 20, 30, 20);
        report(&format!("innovation pack to wire (b={bits})"), &s, Some(p * 4));

        let bytes = qi.encode();
        let mut rx = QuantizedInnovation { radius: 0.0, codes: Vec::with_capacity(p), bits };
        let s = sample(
            || {
                QuantizedInnovation::decode_into(&bytes, bits, p, &mut rx).unwrap();
                black_box(&rx);
            },
            20,
            30,
            20,
        );
        report(&format!("innovation unpack from wire (b={bits})"), &s, Some(p * 4));

        let s = sample(
            || {
                q.dequantize_into(&qi, &qp, &mut q_new);
                black_box(&q_new);
            },
            20,
            30,
            20,
        );
        report(&format!("server dequantize+absorb core (b={bits})"), &s, Some(p * 4));
    }

    let qs = QsgdQuantizer::new(3);
    let mut r2 = Rng::new(2);
    let s = sample(|| { black_box(qs.quantize(&g, &mut r2)); }, 10, 20, 10);
    report("qsgd quantize (b=3)", &s, Some(p * 4));

    let sp = Sparsifier::new(0.25);
    let mut r3 = Rng::new(3);
    let s = sample(|| { black_box(sp.sparsify(&g, &mut r3)); }, 10, 20, 10);
    report("sparsify (keep 25%)", &s, Some(p * 4));
}

fn bench_criterion() {
    println!("\n== L3 hot path: LAQ selection criterion ==");
    use laq::coordinator::DeltaHistory;
    let mut h = DeltaHistory::new(10);
    for i in 0..10 {
        h.push(i as f64);
    }
    let xi = vec![0.08; 10];
    let s = sample(|| { black_box(h.weighted_sum(black_box(&xi))); }, 100, 30, 1000);
    report("criterion rhs (D=10 weighted history)", &s, None);

    let p = 7840;
    let mut rng = Rng::new(4);
    let a: Vec<f32> = (0..p).map(|_| rng.normal() as f32).collect();
    let b: Vec<f32> = (0..p).map(|_| rng.normal() as f32).collect();
    let s = sample(
        || {
            black_box(laq::util::tensor::norm2_sq_diff(black_box(&a), black_box(&b)));
        },
        50,
        30,
        200,
    );
    report("criterion lhs ||Q_prev - Q_new||² (p=7840)", &s, Some(p * 8));
}

/// Tentpole bench: the sharded server's wire phase — per-upload
/// `absorb_lazy` (fused dequantize + aggregate + mirror commit) followed
/// by `apply_update`, swept over shard counts and parameter dimensions.
/// The p ≈ 512k case is the transformer regime the sharding targets; the
/// shards=1 baseline runs the identical fused code on one thread.
fn bench_server_sharded(quick: bool, entries: &mut Vec<Json>) {
    println!("\n== sharded server: absorb_lazy × M + apply_update, per round ==");
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    println!("   (host cores: {cores}; caller participates in every shard fan-out)");
    let m_workers = 5;
    let bits = 3;
    for &p in &[7840usize, 512 * 1024] {
        // one realistic innovation payload per worker (radii differ)
        let q = InnovationQuantizer::new(bits);
        let mut rng = Rng::new(7);
        let zeros = vec![0.0f32; p];
        let payloads: Vec<Payload> = (0..m_workers)
            .map(|_| {
                let g: Vec<f32> = (0..p).map(|_| rng.normal() as f32).collect();
                let (qi, _) = q.quantize(&g, &zeros);
                Payload::Innovation(qi)
            })
            .collect();
        let mut p50_shard1 = f64::NAN;
        for &shards in &[1usize, 2, 4, 8] {
            let mut srv = ServerState::new(p, m_workers, bits, 10, vec![0.0; p]);
            srv.set_shards(shards);
            let runners = srv.shard_runners();
            let (w, smp, it) = if quick {
                (1, 5, 1)
            } else if p >= 100_000 {
                (2, 12, 2)
            } else {
                (5, 20, 5)
            };
            let s = sample(
                || {
                    for m in 0..m_workers {
                        srv.absorb_lazy(m, &payloads[m]).unwrap();
                    }
                    black_box(srv.apply_update(0.02));
                },
                w,
                smp,
                it,
            );
            // bytes touched per round: M × (codes r + mirror rw + agg rw) + θ rw
            let bytes = m_workers * p * (4 + 8 + 8) + p * 8;
            let name = format!("absorb+apply p={p:<7} shards={shards} ({runners} runners)");
            let summ = report(&name, &s, Some(bytes));
            entries.push(json_entry("server_absorb_apply", "absorb+apply", p, shards, runners, &summ));
            if shards == 1 {
                p50_shard1 = summ.p50;
            } else {
                println!(
                    "{:<44} {:.2}× p50 speedup vs shards=1",
                    format!("  -> p={p} shards={shards}"),
                    p50_shard1 / summ.p50
                );
            }
        }
    }
}

/// Tentpole bench: the block-tiled kernel twins vs their scalar
/// references at transformer-scale dimensions — p = 512k (the sharding
/// regime), 8M (GPT-2-small order) and 64M (out-of-core order).  Every
/// twin pair is bit-identical (pinned by `kernel_equivalence.rs`), so
/// this sweep measures pure wall-clock: the tiled column must never lose
/// to scalar by more than noise, and CI gates its p50s through the
/// `kernel_sweep` group in BENCH_trainer.json.  The 64M points run at
/// minimal sampling (the working set alone is ~1 GB); trainer-level
/// benches stop at 8M — the 64M regime is covered here at the kernel
/// level where the memory footprint stays bounded.
fn bench_kernel_sweep(quick: bool, entries: &mut Vec<Json>) {
    use laq::coordinator::server::{
        absorb_innovation_range_scalar, absorb_innovation_range_tiled,
    };
    use laq::util::bitio::{
        pack_codes_scalar, pack_codes_tiled, unpack_codes_into_scalar,
        unpack_codes_into_tiled, BitReader, BitWriter,
    };
    use laq::util::tensor::{dot_f32_scalar, dot_f32_tiled};

    println!("\n== kernel twins: scalar vs block-tiled at transformer scale ==");
    println!("   (bit-identical by contract — wall-clock only; b=3 codecs)");
    let bits = 3u32;
    let kernel_entry = |kernel: &str, mode: &str, p: usize, s: &Summary| {
        Json::obj(vec![
            ("group", Json::Str("kernel_sweep".into())),
            ("bench", Json::Str(format!("{kernel}_{mode}_p{p}"))),
            ("kernel", Json::Str(kernel.into())),
            ("mode", Json::Str(mode.into())),
            ("p", Json::Num(p as f64)),
            ("p50_s", Json::Num(s.p50)),
            ("p99_s", Json::Num(s.p99)),
            ("mean_s", Json::Num(s.mean)),
        ])
    };
    for &p in &[512 * 1024usize, 8 * 1024 * 1024, 64 * 1024 * 1024] {
        // minimal sampling at the big end: the sweep is a trajectory
        // tracker, not a microscope
        let (w, smp, it) = if p >= 32 * 1024 * 1024 {
            (1, 3, 1)
        } else if quick {
            (1, 4, 1)
        } else {
            (2, 10, 2)
        };
        let mut rng = Rng::new(11);
        let g: Vec<f32> = (0..p).map(|_| rng.normal() as f32).collect();
        let qp: Vec<f32> = (0..p).map(|_| rng.normal() as f32).collect();

        type DotFn = fn(&[f32], &[f32]) -> f32;
        for (mode, dot) in
            [("scalar", dot_f32_scalar as DotFn), ("tiled", dot_f32_tiled as DotFn)]
        {
            let s = sample(|| { black_box(dot(black_box(&g), black_box(&qp))); }, w, smp, it);
            let summ = report(&format!("dot_f32 [{mode}] p={p}"), &s, Some(p * 8));
            entries.push(kernel_entry("dot_f32", mode, p, &summ));
        }

        let q = InnovationQuantizer::new(bits);
        let mut codes: Vec<u32> = Vec::with_capacity(p);
        let mut q_new = vec![0.0f32; p];
        let s = sample(
            || { black_box(q.quantize_into_scalar(&g, &qp, &mut codes, &mut q_new)); },
            w, smp, it,
        );
        let summ = report(&format!("quantize [scalar] p={p}"), &s, Some(p * 4));
        entries.push(kernel_entry("quantize", "scalar", p, &summ));
        let s = sample(
            || { black_box(q.quantize_into_tiled(&g, &qp, &mut codes, &mut q_new)); },
            w, smp, it,
        );
        let summ = report(&format!("quantize [tiled] p={p}"), &s, Some(p * 4));
        entries.push(kernel_entry("quantize", "tiled", p, &summ));
        let radius = q.quantize_into_scalar(&g, &qp, &mut codes, &mut q_new);

        type PackFn = fn(&[u32], u32, &mut BitWriter);
        let mut bw = BitWriter::with_capacity_bits(p * bits as usize);
        for (mode, pack) in
            [("scalar", pack_codes_scalar as PackFn), ("tiled", pack_codes_tiled as PackFn)]
        {
            let s = sample(
                || {
                    bw.clear();
                    pack(black_box(&codes), bits, &mut bw);
                    black_box(bw.as_bytes());
                },
                w, smp, it,
            );
            let summ = report(&format!("pack b={bits} [{mode}] p={p}"), &s, Some(p * 4));
            entries.push(kernel_entry("pack", mode, p, &summ));
        }

        type UnpackFn = fn(&mut BitReader, u32, usize, &mut Vec<u32>) -> Option<()>;
        let bytes = bw.into_bytes();
        let mut out: Vec<u32> = Vec::with_capacity(p);
        for (mode, unpack) in [
            ("scalar", unpack_codes_into_scalar as UnpackFn),
            ("tiled", unpack_codes_into_tiled as UnpackFn),
        ] {
            let s = sample(
                || {
                    let mut r = BitReader::new(&bytes);
                    unpack(&mut r, bits, p, &mut out).unwrap();
                    black_box(&out);
                },
                w, smp, it,
            );
            let summ = report(&format!("unpack b={bits} [{mode}] p={p}"), &s, Some(p * 4));
            entries.push(kernel_entry("unpack", mode, p, &summ));
        }

        // fused dequantize + aggregate + mirror-commit — the server's
        // per-upload sweep; reuse the big buffers as agg/mirror
        type AbsorbFn = fn(&[u32], f32, f32, &mut [f32], &mut [f32]);
        let two_tau_r = 2.0 * radius / ((1u32 << bits) - 1) as f32;
        let mut agg = q_new;
        let mut mir = g;
        for (mode, absorb) in [
            ("scalar", absorb_innovation_range_scalar as AbsorbFn),
            ("tiled", absorb_innovation_range_tiled as AbsorbFn),
        ] {
            let s = sample(
                || {
                    absorb(black_box(&codes), radius, two_tau_r, &mut agg, &mut mir);
                    black_box(&agg);
                },
                w, smp, it,
            );
            let summ = report(&format!("absorb [{mode}] p={p}"), &s, Some(p * (4 + 8 + 8)));
            entries.push(kernel_entry("absorb", mode, p, &summ));
        }
    }
}

fn bench_trainer_steps() {
    println!("\n== end-to-end iteration latency per algorithm (ijcnn1 1k × 5 workers) ==");
    for algo in Algo::all() {
        let mut cfg = RunCfg::paper_logreg(algo);
        cfg.data.name = "ijcnn1".into();
        cfg.data.n_train = 1_000;
        cfg.data.n_test = 100;
        cfg.workers = 5;
        cfg.batch = 100;
        cfg.iters = 10_000; // not used; we step manually
        let mut t = build_native(&cfg).unwrap();
        let s = sample(|| { black_box(t.step().unwrap()); }, 5, 20, 5);
        report(&format!("trainer step [{}]", algo.name()), &s, None);
    }
}

/// Sequential vs parallel worker fan-out at growing M — the regime where
/// lazy skipping pays off most is exactly where the sequential per-worker
/// loop used to scale linearly in wall-clock.
fn bench_parallel_fanout(entries: &mut Vec<Json>) {
    println!("\n== worker fan-out: sequential (threads=1) vs parallel (threads=4) ==");
    println!("   (mnist-like logreg, p = 7840, 50 rows/worker, LAQ b=3)");
    for m in [5usize, 20, 100] {
        let mut p50 = [0.0f64; 2];
        for (ti, threads) in [1usize, 4].into_iter().enumerate() {
            let mut cfg = RunCfg::paper_logreg(Algo::Laq);
            cfg.data.n_train = 50 * m;
            cfg.data.n_test = 100;
            cfg.workers = m;
            cfg.threads = threads;
            // pin the server to one shard so the threads sweep isn't
            // confounded by a LAQ_SHARDS env default
            cfg.server_shards = 1;
            let mut t = build_native(&cfg).unwrap();
            let (warmup, samples, iters_per) = if m >= 100 { (2, 10, 2) } else { (3, 15, 3) };
            let s = sample(|| { black_box(t.step().unwrap()); }, warmup, samples, iters_per);
            let summ = report(&format!("trainer step [LAQ] M={m:<3} threads={threads}"), &s, None);
            entries.push(json_entry("worker_fanout", &format!("step_laq_m{m}"), 7840, 1, threads, &summ));
            p50[ti] = summ.p50;
        }
        println!(
            "{:<44} {:.2}× step-throughput speedup",
            format!("  -> M={m} parallel vs sequential"),
            p50[0] / p50[1]
        );
    }
}

/// Cheap deterministic O(p) gradient oracle for the transformer-dim wire
/// benches: the gradient varies every step (so the lazy criterion keeps
/// producing fresh innovations) but costs one linear sweep — putting the
/// wire phase, not the model, on the critical path.
struct SynthGrad {
    dim: usize,
    seed: u64,
    k: u64,
}

impl WorkerGrad for SynthGrad {
    fn dim(&self) -> usize {
        self.dim
    }

    fn full(&mut self, theta: &[f32]) -> laq::Result<(f64, Vec<f32>)> {
        let mut g = vec![0.0f32; self.dim];
        let l = self.full_into(theta, &mut g)?;
        Ok((l, g))
    }

    fn batch(&mut self, theta: &[f32], _rows: &[usize]) -> laq::Result<(f64, Vec<f32>)> {
        self.full(theta)
    }

    fn full_into(&mut self, theta: &[f32], grad_out: &mut [f32]) -> laq::Result<f64> {
        self.k += 1;
        let a = ((self.seed % 13) as f32 + 1.0) * 0.01;
        let phase = (self.k % 7) as f32 * 0.1;
        for (i, o) in grad_out.iter_mut().enumerate() {
            *o = theta[i] * 1e-3 + a * (((i % 97) as f32) * 0.01 + phase);
        }
        Ok(1.0)
    }

    fn batch_into(&mut self, theta: &[f32], _rows: &[usize], grad_out: &mut [f32]) -> laq::Result<f64> {
        self.full_into(theta, grad_out)
    }

    fn shard_len(&self) -> usize {
        4
    }
}

fn wire_cfg(m: usize, wire: WireMode) -> RunCfg {
    let mut cfg = RunCfg::paper_logreg(Algo::Laq);
    cfg.workers = m;
    cfg.threads = 2;
    cfg.server_shards = 2;
    cfg.wire_mode = wire;
    cfg.staleness_bound = 4;
    cfg
}

/// Trainer over the real mnist-like logreg workers (p = 7840).
fn logreg_wire_trainer(m: usize, wire: WireMode) -> Trainer {
    let mut cfg = wire_cfg(m, wire);
    cfg.data.n_train = 16 * m; // 16 rows/worker: wire phase on the critical path
    cfg.data.n_test = 40;
    build_native(&cfg).unwrap()
}

/// Trainer over synthetic oracles at an arbitrary dimension (p = 512k).
fn synth_wire_trainer(m: usize, p: usize, wire: WireMode) -> Trainer {
    let cfg = wire_cfg(m, wire);
    let nodes: Vec<WorkerNode<dyn WorkerGrad>> = (0..m)
        .map(|i| {
            let w: Box<dyn WorkerGrad> =
                Box::new(SynthGrad { dim: p, seed: i as u64, k: 0 });
            WorkerNode::new(w, cfg.bits, LazyCodec::Quantized)
        })
        .collect();
    Trainer::assemble(cfg, nodes, vec![0.0; p], None, LatencyModel::default()).unwrap()
}

/// Tentpole bench: end-to-end step throughput, sync vs async wire phase,
/// swept over worker count M and parameter dimension p — the async
/// pipeline overlaps compute/wire/absorb, so its win grows with M (the
/// sync wire phase serializes Σ_m absorb on the coordinator).  Emits the
/// `trainer_wire` group into BENCH_trainer.json.
fn bench_trainer_wire(quick: bool, entries: &mut Vec<Json>) {
    println!("\n== trainer step throughput: sync vs async vs async-cross wire phase ==");
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    println!("   (host cores: {cores}; threads=2, shards=2, LAQ b=3, staleness=4)");
    // 8M is the trainer-level ceiling (each worker holds a p-dim mirror,
    // so M × p already dominates RAM); the 64M regime is swept at the
    // kernel level by `bench_kernel_sweep` instead
    let combos: &[(usize, usize)] = if quick {
        &[(5, 7840), (100, 7840), (5, 512 * 1024), (2, 8 * 1024 * 1024)]
    } else {
        &[
            (5, 7840),
            (20, 7840),
            (100, 7840),
            (5, 512 * 1024),
            (20, 512 * 1024),
            (100, 512 * 1024),
            (2, 8 * 1024 * 1024),
            (5, 8 * 1024 * 1024),
        ]
    };
    for &(m, p) in combos {
        let mut p50_sync = f64::NAN;
        for wire in [WireMode::Sync, WireMode::Async, WireMode::AsyncCross] {
            let mut t = if p == 7840 {
                logreg_wire_trainer(m, wire)
            } else {
                synth_wire_trainer(m, p, wire)
            };
            let (w, smp, it) = if quick {
                (1, 4, 1)
            } else if p >= 100_000 || m >= 100 {
                (1, 8, 1)
            } else {
                (3, 15, 3)
            };
            let s = sample(|| { black_box(t.step().unwrap()); }, w, smp, it);
            let name = format!("trainer step [LAQ] M={m:<3} p={p:<6} wire={}", wire.name());
            let summ = report(&name, &s, None);
            entries.push(Json::obj(vec![
                ("group", Json::Str("trainer_wire".into())),
                ("bench", Json::Str(format!("step_m{m}_p{p}_{}", wire.name()))),
                ("m", Json::Num(m as f64)),
                ("p", Json::Num(p as f64)),
                ("shards", Json::Num(2.0)),
                ("threads", Json::Num(2.0)),
                ("wire", Json::Str(wire.name().into())),
                ("p50_s", Json::Num(summ.p50)),
                ("p99_s", Json::Num(summ.p99)),
                ("mean_s", Json::Num(summ.mean)),
            ]));
            if wire == WireMode::Sync {
                p50_sync = summ.p50;
            } else {
                println!(
                    "{:<44} {:.2}× p50 step speedup {} vs sync",
                    format!("  -> M={m} p={p}"),
                    p50_sync / summ.p50,
                    wire.name()
                );
            }
        }
    }
}

/// Tentpole bench: the dial-a-bit win — total traffic and final loss at
/// a matched round count, fixed b=3 vs the adaptive schedules over the
/// strongly convex logreg benchmark, plus the bidirectional row: the
/// same adaptive uplink with the θ broadcast quantized
/// (`downlink = quantized`).  Bits are recorded per direction
/// (`uplink_bits` / `downlink_bits` / `total_bits` — the downlink has
/// always been billed into sim_time, so the total is only honest with
/// both), and the quantized-downlink row must land near the
/// exact-downlink final loss on strictly fewer total bits (the hard
/// contract lives in `rust/tests/downlink.rs`).  Emits the
/// `trainer_bits` group into BENCH_trainer.json.
fn bench_bit_schedules(quick: bool, entries: &mut Vec<Json>) {
    println!("\n== dial-a-bit: total traffic at matched round count (LAQ logreg, sync) ==");
    let iters = if quick { 150 } else { 400 };
    println!(
        "   (mnist-like p=7840, M=4, {iters} rounds, fixed b=3 vs adaptive [2,3] vs quantized downlink [2,8])"
    );
    let mut fixed_bits_total = 0u64;
    let mut fixed_loss = f64::NAN;
    let mut exact_down_total = 0u64;
    let mut exact_down_loss = f64::NAN;
    for (label, kind, bmin, bmax, downlink) in [
        ("fixed-b3", BitScheduleKind::Fixed, 3u32, 3u32, DownlinkMode::Exact),
        ("round-decay-2-3", BitScheduleKind::RoundDecay, 2, 3, DownlinkMode::Exact),
        ("innovation-2-3", BitScheduleKind::Innovation, 2, 3, DownlinkMode::Exact),
        ("innovation-2-3+down-2-8", BitScheduleKind::Innovation, 2, 3, DownlinkMode::Quantized),
    ] {
        let mut cfg = RunCfg::paper_logreg(Algo::Laq);
        cfg.data.n_train = 240;
        cfg.data.n_test = 60;
        cfg.workers = 4;
        cfg.threads = 1;
        cfg.server_shards = 1;
        cfg.wire_mode = WireMode::Sync;
        cfg.staleness_bound = 0;
        cfg.bits = 3;
        cfg.bit_schedule = kind;
        cfg.bits_min = bmin;
        cfg.bits_max = bmax;
        cfg.downlink = downlink;
        cfg.down_bits_min = 2;
        cfg.down_bits_max = 8;
        cfg.iters = iters;
        let mut t = build_native(&cfg).unwrap();
        let t0 = Instant::now();
        let mut last_loss = f64::NAN;
        for _ in 0..iters {
            last_loss = t.step().unwrap().loss;
        }
        let wall = t0.elapsed().as_secs_f64();
        let up = t.net.uplink_bits();
        let down = t.net.downlink_bits();
        let total = up + down;
        let rounds = t.net.uplink_rounds();
        println!(
            "{label:<24} rounds {rounds:>5}  bits up {up:>12} + down {down:>12} = {total:>12}  final loss {last_loss:.6e}  ({wall:.2}s)"
        );
        if kind == BitScheduleKind::Fixed {
            fixed_bits_total = total;
            fixed_loss = last_loss;
        } else if fixed_bits_total > 0 {
            println!(
                "{:<24} {:.3}× the fixed total-bit budget, loss Δ {:+.2e}",
                format!("  -> {label}"),
                total as f64 / fixed_bits_total as f64,
                last_loss - fixed_loss
            );
        }
        if label == "innovation-2-3" {
            exact_down_total = total;
            exact_down_loss = last_loss;
        } else if downlink == DownlinkMode::Quantized && exact_down_total > 0 {
            println!(
                "{:<24} {:.3}× the exact-downlink total, loss Δ {:+.2e} (quantized θ broadcast)",
                format!("  -> {label}"),
                total as f64 / exact_down_total as f64,
                last_loss - exact_down_loss
            );
        }
        entries.push(Json::obj(vec![
            ("group", Json::Str("trainer_bits".into())),
            ("bench", Json::Str(format!("laq_{label}"))),
            ("schedule", Json::Str(label.into())),
            ("downlink", Json::Str(downlink.name().into())),
            ("iters", Json::Num(iters as f64)),
            ("rounds", Json::Num(rounds as f64)),
            ("uplink_bits", Json::Num(up as f64)),
            ("downlink_bits", Json::Num(down as f64)),
            ("total_bits", Json::Num(total as f64)),
            ("final_loss", Json::Num(last_loss)),
            ("wall_s", Json::Num(wall)),
        ]));
    }
}

/// Scenario bench: the robustness tax — traffic, simulated wall-clock,
/// rejected uploads, and final full-fleet loss for the same LAQ run
/// fault-free vs under a heavy-tailed straggler fleet vs an elastic
/// mid-run dropout.  Emits the `trainer_scenario` group into
/// BENCH_trainer.json so CI can watch how much convergence the fault
/// model costs as the engine evolves.
fn bench_trainer_scenario(quick: bool, entries: &mut Vec<Json>) {
    use laq::config::WorkerFaults;
    println!("\n== scenario engine: fault-free vs straggler vs dropout (LAQ logreg, sync) ==");
    let iters = if quick { 100 } else { 300 };
    println!("   (mnist-like p=7840, M=4, {iters} rounds, Pareto stragglers / mid-run outage)");
    let fleets: [(&str, Vec<WorkerFaults>); 3] = [
        ("fault-free", vec![]),
        (
            "straggler-heavy-tail",
            vec![
                WorkerFaults {
                    worker: 1,
                    straggle_alpha: Some(1.2),
                    deadline: 5.0,
                    ..WorkerFaults::default()
                },
                WorkerFaults {
                    worker: 3,
                    straggle_alpha: Some(2.5),
                    deadline: 8.0,
                    ..WorkerFaults::default()
                },
            ],
        ),
        (
            "dropout-mid-run",
            vec![WorkerFaults {
                worker: 2,
                drop_from: Some(iters / 4),
                drop_until: Some(iters / 2),
                ..WorkerFaults::default()
            }],
        ),
    ];
    let mut free_loss = f64::NAN;
    for (label, fleet) in fleets {
        let mut cfg = RunCfg::paper_logreg(Algo::Laq);
        cfg.data.n_train = 240;
        cfg.data.n_test = 60;
        cfg.workers = 4;
        cfg.threads = 1;
        cfg.server_shards = 1;
        cfg.wire_mode = WireMode::Sync;
        cfg.staleness_bound = 0;
        cfg.iters = iters;
        cfg.scenario.workers = fleet;
        let mut t = build_native(&cfg).unwrap();
        let t0 = Instant::now();
        for _ in 0..iters {
            t.step().unwrap();
        }
        let wall = t0.elapsed().as_secs_f64();
        // full-fleet loss: the per-step trace excludes dropped workers'
        // shards, so only eval_full compares fleets apples to apples
        let (loss, _) = t.eval_full().unwrap();
        let up = t.net.uplink_bits();
        let down = t.net.downlink_bits();
        let rounds = t.net.uplink_rounds();
        let sim = t.net.sim_time();
        let rejected = t.scenario_rejections();
        println!(
            "{label:<24} rounds {rounds:>5}  bits up {up:>12} + down {down:>12}  sim {sim:>9.3}s  rejected {rejected:>3}  full loss {loss:.6e}  ({wall:.2}s)"
        );
        if label == "fault-free" {
            free_loss = loss;
        } else {
            println!(
                "{:<24} loss Δ {:+.2e} vs fault-free",
                format!("  -> {label}"),
                loss - free_loss
            );
        }
        entries.push(Json::obj(vec![
            ("group", Json::Str("trainer_scenario".into())),
            ("bench", Json::Str(format!("laq_{label}"))),
            ("scenario", Json::Str(label.into())),
            ("iters", Json::Num(iters as f64)),
            ("rounds", Json::Num(rounds as f64)),
            ("uplink_bits", Json::Num(up as f64)),
            ("downlink_bits", Json::Num(down as f64)),
            ("sim_time_s", Json::Num(sim)),
            ("rejected_uploads", Json::Num(rejected as f64)),
            ("final_loss", Json::Num(loss)),
            ("wall_s", Json::Num(wall)),
        ]));
    }
}

/// Resilience bench: what the self-healing coordinator buys back — the
/// same heavy-tail straggler fleet run resilience-off vs resilience-on
/// (reduced cadence + retry ladder + quorum), reporting simulated
/// wall-clock, per-direction traffic, demotions/retries/clamps, and the
/// final full-fleet loss.  Emits the `trainer_resilience` group into
/// BENCH_trainer.json; the hard contract (less sim_time, no more uplink
/// bits, loss within tolerance) lives in `rust/tests/resilience.rs`.
fn bench_trainer_resilience(quick: bool, entries: &mut Vec<Json>) {
    use laq::config::{ResilienceCfg, WorkerFaults};
    println!("\n== self-healing coordinator: straggler fleet, resilience off vs on (LAQ logreg, sync) ==");
    let iters = if quick { 100 } else { 300 };
    println!("   (mnist-like p=7840, M=4, {iters} rounds, Pareto α=1.2 straggler, cadence 4 + 2 retries + 0.75 quorum)");
    let fleet = || {
        vec![
            WorkerFaults {
                worker: 1,
                straggle_alpha: Some(1.2),
                deadline: 3.0,
                ..WorkerFaults::default()
            },
            WorkerFaults { worker: 2, corrupt_rate: 0.1, ..WorkerFaults::default() },
        ]
    };
    let healing = ResilienceCfg {
        cadence: 4,
        miss_threshold: 1,
        restore_rounds: 30,
        max_retries: 2,
        backoff_base: 1e-3,
        backoff_cap: 4e-3,
        quorum: 0.75,
        ..ResilienceCfg::default()
    };
    let mut off_sim = f64::NAN;
    let mut off_loss = f64::NAN;
    for (label, resilience) in [("resilience-off", ResilienceCfg::default()), ("resilience-on", healing)] {
        let mut cfg = RunCfg::paper_logreg(Algo::Laq);
        cfg.data.n_train = 240;
        cfg.data.n_test = 60;
        cfg.workers = 4;
        cfg.threads = 1;
        cfg.server_shards = 1;
        cfg.wire_mode = WireMode::Sync;
        cfg.staleness_bound = 0;
        cfg.iters = iters;
        cfg.scenario.workers = fleet();
        cfg.resilience = resilience;
        let mut t = build_native(&cfg).unwrap();
        let t0 = Instant::now();
        for _ in 0..iters {
            t.step().unwrap();
        }
        let wall = t0.elapsed().as_secs_f64();
        let (loss, _) = t.eval_full().unwrap();
        let up = t.net.uplink_bits();
        let down = t.net.downlink_bits();
        let rounds = t.net.uplink_rounds();
        let sim = t.net.sim_time();
        let rejected = t.scenario_rejections();
        let (demotions, retries, clamps) = t.resilience_stats();
        println!(
            "{label:<24} rounds {rounds:>5}  bits up {up:>12} + down {down:>12}  sim {sim:>9.3}s  rejected {rejected:>3}  demoted {demotions}  retries {retries}  clamped {clamps}  full loss {loss:.6e}  ({wall:.2}s)"
        );
        if label == "resilience-off" {
            off_sim = sim;
            off_loss = loss;
        } else {
            println!(
                "{:<24} {:.3}× the resilience-off sim_time, loss Δ {:+.2e}",
                format!("  -> {label}"),
                sim / off_sim,
                loss - off_loss
            );
        }
        entries.push(Json::obj(vec![
            ("group", Json::Str("trainer_resilience".into())),
            ("bench", Json::Str(format!("laq_{label}"))),
            ("iters", Json::Num(iters as f64)),
            ("rounds", Json::Num(rounds as f64)),
            ("uplink_bits", Json::Num(up as f64)),
            ("downlink_bits", Json::Num(down as f64)),
            ("sim_time_s", Json::Num(sim)),
            ("rejected_uploads", Json::Num(rejected as f64)),
            ("demotions", Json::Num(demotions as f64)),
            ("retries", Json::Num(retries as f64)),
            ("quorum_clamps", Json::Num(clamps as f64)),
            ("final_loss", Json::Num(loss)),
            ("wall_s", Json::Num(wall)),
        ]));
    }
}

fn write_trainer_json(entries: Vec<Json>) {
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let doc = Json::obj(vec![
        ("host", Json::obj(vec![("cores", Json::Num(cores as f64))])),
        ("entries", Json::Arr(entries)),
    ]);
    let path = "BENCH_trainer.json";
    match std::fs::write(path, doc.to_string_pretty()) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => println!("\nWARN: could not write {path}: {e}"),
    }
}

fn bench_gradient_backends() {
    println!("\n== gradient evaluation (the dominant per-iteration cost) ==");
    use laq::model::logreg::LogRegWorker;
    use laq::model::mlp::MlpWorker;
    use laq::model::{LossCfg, WorkerGrad};

    let tt = laq::data::synth::mnist_like(1_000, 10, 5);
    let lc = LossCfg { n_global: 10_000, l2: 0.01, n_workers: 10 };
    let mut w = LogRegWorker::new(tt.train.clone(), lc);
    let theta = vec![0.01f32; 7840];
    let s = sample(|| { black_box(w.full(&theta).unwrap()); }, 3, 15, 2);
    report("logreg grad, shard 1000×784×10 (native)", &s, None);

    let mut mw = MlpWorker::new(tt.train.clone(), 64, lc);
    let p = 784 * 64 + 64 + 64 * 10 + 10;
    let thm = vec![0.01f32; p];
    let s = sample(|| { black_box(mw.full(&thm).unwrap()); }, 2, 10, 1);
    report("mlp grad, shard 1000×784-64-10 (native)", &s, None);
}

fn bench_experiments() {
    println!("\n== paper tables/figures, reduced-scale regeneration ==");
    let opts = ExpOpts {
        quick: true,
        out_dir: "results/bench".into(),
        backend: laq::config::Backend::Native,
        seed: 1,
    };
    // one bench per table/figure; each prints its own comparison rows
    for id in ["fig3", "fig4", "fig5", "fig6", "fig7", "fig8", "table2", "table3", "prop1"] {
        let t0 = Instant::now();
        match experiments::run(id, &opts) {
            Ok(report) => {
                println!("\n--- {id} ({:.1?}) ---", t0.elapsed());
                println!("{report}");
            }
            Err(e) => println!("--- {id} FAILED: {e} ---"),
        }
    }
    let _ = ModelKind::LogReg; // keep import meaningful if ids change
}

fn write_bench_json(entries: Vec<Json>) {
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let doc = Json::obj(vec![
        ("host", Json::obj(vec![("cores", Json::Num(cores as f64))])),
        ("entries", Json::Arr(entries)),
    ]);
    let path = "BENCH_server.json";
    match std::fs::write(path, doc.to_string_pretty()) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => println!("\nWARN: could not write {path}: {e}"),
    }
}

fn main() {
    // `cargo bench` passes --bench; ignore args
    laq::util::logging::init();
    let quick = std::env::var("LAQ_BENCH_QUICK").map(|v| v == "1").unwrap_or(false);
    let mut entries: Vec<Json> = Vec::new();
    let mut trainer_entries: Vec<Json> = Vec::new();
    let t0 = Instant::now();
    if quick {
        println!("LAQ bench harness — QUICK smoke (sharded server + kernel sweep + trainer wire/bits groups)");
        bench_server_sharded(true, &mut entries);
        bench_kernel_sweep(true, &mut trainer_entries);
        bench_trainer_wire(true, &mut trainer_entries);
        bench_bit_schedules(true, &mut trainer_entries);
        bench_trainer_scenario(true, &mut trainer_entries);
        bench_trainer_resilience(true, &mut trainer_entries);
    } else {
        println!("LAQ bench harness (offline substitute for criterion)");
        bench_codecs();
        bench_criterion();
        bench_gradient_backends();
        bench_trainer_steps();
        bench_parallel_fanout(&mut entries);
        bench_server_sharded(false, &mut entries);
        bench_kernel_sweep(false, &mut trainer_entries);
        bench_trainer_wire(false, &mut trainer_entries);
        bench_bit_schedules(false, &mut trainer_entries);
        bench_trainer_scenario(false, &mut trainer_entries);
        bench_trainer_resilience(false, &mut trainer_entries);
        bench_experiments();
    }
    write_bench_json(entries);
    write_trainer_json(trainer_entries);
    println!("\ntotal bench wall time: {:.1?}", t0.elapsed());
}
