#!/usr/bin/env python3
"""Bench-regression gate: compare a fresh BENCH_*.json against the
checked-in baseline and fail on sampled-timing regressions.

Usage: bench_gate.py BASELINE.json FRESH.json [BUDGET]

Entries are keyed by (group, bench); only entries carrying a sampled
``p50_s`` are gated (the trajectory groups — trainer_bits,
trainer_scenario, trainer_resilience — record counters and losses, not
wall-time percentiles, and drift there is pinned by the test suite
instead).  A fresh p50 more than BUDGET (default 15%) above the baseline
fails the gate; disappeared or brand-new benches are reported but do not
fail, so adding a group does not require regenerating every baseline at
once.

A baseline may carry two optional top-level keys:

* ``"bootstrap": true`` — the file is a placeholder checked in before any
  trusted run existed (e.g. authored on a machine with no toolchain).
  The comparison still prints, but the gate exits 0 whatever it finds;
  ``ci.sh`` refreshes bootstrap-marked baselines from the fresh run so
  committing the CI artifact arms the gate.
* ``"budgets": {"<group>": 0.25, ...}`` — per-group budget overrides.
  Kernel-twin micro-benches (``kernel_sweep``) time single memory-bound
  sweeps and jitter more than the trainer-step groups, so they carry a
  wider budget than the CLI default.

Stdlib only — CI has no third-party Python.
"""

import json
import sys


def load(path):
    with open(path) as fh:
        return json.load(fh)


def timed_entries(doc):
    out = {}
    for e in doc.get("entries", []):
        if "p50_s" in e:
            out[(e.get("group", "?"), e.get("bench", "?"))] = float(e["p50_s"])
    return out


def main():
    if len(sys.argv) < 3:
        sys.exit(__doc__)
    baseline_path, fresh_path = sys.argv[1], sys.argv[2]
    default_budget = float(sys.argv[3]) if len(sys.argv) > 3 else 0.15
    baseline_doc = load(baseline_path)
    baseline = timed_entries(baseline_doc)
    fresh = timed_entries(load(fresh_path))
    bootstrap = bool(baseline_doc.get("bootstrap", False))
    budgets = baseline_doc.get("budgets", {})

    failures = []
    for key in sorted(baseline.keys() & fresh.keys()):
        base, now = baseline[key], fresh[key]
        if base <= 0.0:
            continue
        budget = float(budgets.get(key[0], default_budget))
        ratio = now / base
        flag = "FAIL" if ratio > 1.0 + budget else "ok"
        print(
            f"  {flag:<4} {key[0]}/{key[1]}: p50 {base:.3e}s -> {now:.3e}s "
            f"({ratio:.2f}x, budget {budget:.0%})"
        )
        if ratio > 1.0 + budget:
            failures.append((key, base, now, ratio))
    for key in sorted(baseline.keys() - fresh.keys()):
        print(f"  note {key[0]}/{key[1]}: in baseline but missing from this run")
    for key in sorted(fresh.keys() - baseline.keys()):
        print(f"  note {key[0]}/{key[1]}: new bench, no baseline yet")

    if failures and bootstrap:
        print(
            f"note: {len(failures)} over-budget bench(es) ignored — "
            f"{baseline_path} is marked bootstrap (advisory only)"
        )
        return
    if failures:
        print(
            f"FAIL: {len(failures)} bench(es) regressed more than "
            f"their budget over {baseline_path}",
            file=sys.stderr,
        )
        sys.exit(1)
    shared = len(baseline.keys() & fresh.keys())
    tag = " (bootstrap baseline, advisory)" if bootstrap else ""
    print(f"bench gate OK ({shared} benches within budget){tag}")


if __name__ == "__main__":
    main()
