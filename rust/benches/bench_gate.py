#!/usr/bin/env python3
"""Bench-regression gate: compare a fresh BENCH_*.json against the
checked-in baseline and fail on sampled-timing regressions.

Usage: bench_gate.py BASELINE.json FRESH.json [BUDGET]

Entries are keyed by (group, bench); only entries carrying a sampled
``p50_s`` are gated (the trajectory groups — trainer_bits,
trainer_scenario, trainer_resilience — record counters and losses, not
wall-time percentiles, and drift there is pinned by the test suite
instead).  A fresh p50 more than BUDGET (default 15%) above the baseline
fails the gate; disappeared or brand-new benches are reported but do not
fail, so adding a group does not require regenerating every baseline at
once.  Stdlib only — CI has no third-party Python.
"""

import json
import sys


def timed_entries(path):
    with open(path) as fh:
        doc = json.load(fh)
    out = {}
    for e in doc.get("entries", []):
        if "p50_s" in e:
            out[(e.get("group", "?"), e.get("bench", "?"))] = float(e["p50_s"])
    return out


def main():
    if len(sys.argv) < 3:
        sys.exit(__doc__)
    baseline_path, fresh_path = sys.argv[1], sys.argv[2]
    budget = float(sys.argv[3]) if len(sys.argv) > 3 else 0.15
    baseline = timed_entries(baseline_path)
    fresh = timed_entries(fresh_path)

    failures = []
    for key in sorted(baseline.keys() & fresh.keys()):
        base, now = baseline[key], fresh[key]
        if base <= 0.0:
            continue
        ratio = now / base
        flag = "FAIL" if ratio > 1.0 + budget else "ok"
        print(f"  {flag:<4} {key[0]}/{key[1]}: p50 {base:.3e}s -> {now:.3e}s ({ratio:.2f}x)")
        if ratio > 1.0 + budget:
            failures.append((key, base, now, ratio))
    for key in sorted(baseline.keys() - fresh.keys()):
        print(f"  note {key[0]}/{key[1]}: in baseline but missing from this run")
    for key in sorted(fresh.keys() - baseline.keys()):
        print(f"  note {key[0]}/{key[1]}: new bench, no baseline yet")

    if failures:
        print(
            f"FAIL: {len(failures)} bench(es) regressed more than "
            f"{budget:.0%} over {baseline_path}",
            file=sys.stderr,
        )
        sys.exit(1)
    print(f"bench gate OK ({len(baseline.keys() & fresh.keys())} benches within {budget:.0%})")


if __name__ == "__main__":
    main()
