//! Property tests for the quantization codecs (paper §2.1 guarantees) and
//! the [`Payload`] wire invariants the trainer's sequential wire phase
//! relies on: every payload survives the physical encode/decode roundtrip
//! exactly, and `wire_bits()` equals the physically serialized size.

use laq::comm::Payload;
use laq::prop_assert;
use laq::quant::innovation::{InnovationQuantizer, QuantizedInnovation};
use laq::quant::qsgd::{QsgdMessage, QsgdQuantizer};
use laq::quant::signef::SignEfCompressor;
use laq::quant::sparsify::{SparseMessage, Sparsifier};
use laq::util::prop::Prop;
use laq::util::rng::Rng;
use laq::util::tensor::norm_inf_diff;

fn rand_vec(rng: &mut Rng, p: usize, scale: f64) -> Vec<f32> {
    (0..p).map(|_| (rng.normal() * scale) as f32).collect()
}

#[test]
fn innovation_roundtrip_is_bit_exact() {
    Prop::new().check("innovation wire roundtrip", |rng| {
        let p = 1 + rng.below(3000) as usize;
        let bits = 1 + rng.below(8) as u32;
        let scale = 10f64.powf(rng.uniform_range(-4.0, 4.0));
        let g = rand_vec(rng, p, scale);
        let qp = rand_vec(rng, p, scale);
        let q = InnovationQuantizer::new(bits);
        let (qi, _) = q.quantize(&g, &qp);
        let decoded = QuantizedInnovation::decode(&qi.encode(), bits, p)
            .map_err(|e| e.to_string())?;
        prop_assert!(decoded == qi, "roundtrip mismatch p={p} bits={bits}");
        prop_assert!(
            qi.wire_bits() == 32 + bits as usize * p,
            "wire bits formula"
        );
        Ok(())
    });
}

#[test]
fn innovation_error_bounded_by_tau_r() {
    Prop::new().check("||eps||_inf <= tau R", |rng| {
        let p = 1 + rng.below(2000) as usize;
        let bits = 1 + rng.below(8) as u32;
        let g = rand_vec(rng, p, 1.0);
        let qp = rand_vec(rng, p, 1.0);
        let q = InnovationQuantizer::new(bits);
        let (qi, q_new) = q.quantize(&g, &qp);
        let tau = q.tau() as f32;
        let err = norm_inf_diff(&g, &q_new);
        prop_assert!(
            err <= tau * qi.radius * (1.0 + 1e-5) + 1e-30,
            "err {err} > tau*R {}",
            tau * qi.radius
        );
        Ok(())
    });
}

#[test]
fn innovation_codes_fit_bit_width() {
    Prop::new().check("codes in [0, 2^b)", |rng| {
        let p = 1 + rng.below(500) as usize;
        let bits = 1 + rng.below(8) as u32;
        let g = rand_vec(rng, p, 3.0);
        let qp = rand_vec(rng, p, 3.0);
        let (qi, _) = InnovationQuantizer::new(bits).quantize(&g, &qp);
        let max = (1u32 << bits) - 1;
        prop_assert!(
            qi.codes.iter().all(|&c| c <= max),
            "code exceeds width"
        );
        Ok(())
    });
}

#[test]
fn server_reconstruction_equals_worker() {
    // the mirror-consistency property the whole algorithm rests on,
    // through the PHYSICAL wire format
    Prop::new().check("dequantize(encode(quantize)) == worker view", |rng| {
        let p = 1 + rng.below(1000) as usize;
        let bits = 1 + rng.below(8) as u32;
        let q = InnovationQuantizer::new(bits);
        let mut q_prev = rand_vec(rng, p, 1.0);
        // several rounds of drift
        for _ in 0..4 {
            let g = rand_vec(rng, p, 1.0);
            let (qi, q_new_worker) = q.quantize(&g, &q_prev);
            let wire = QuantizedInnovation::decode(&qi.encode(), bits, p)
                .map_err(|e| e.to_string())?;
            let q_new_server = q.dequantize(&wire, &q_prev);
            prop_assert!(
                q_new_worker == q_new_server,
                "mirror drift at p={p} bits={bits}"
            );
            q_prev = q_new_worker;
        }
        Ok(())
    });
}

#[test]
fn qsgd_roundtrip_and_norm_bound() {
    Prop::new().check("qsgd wire + bound", |rng| {
        let p = 1 + rng.below(1000) as usize;
        let bits = 1 + rng.below(8) as u32;
        let g = rand_vec(rng, p, 2.0);
        let q = QsgdQuantizer::new(bits);
        let m = q.quantize(&g, rng);
        let decoded =
            QsgdMessage::decode(&m.encode(), bits, p).map_err(|e| e.to_string())?;
        prop_assert!(decoded == m, "qsgd roundtrip");
        let norm = laq::util::tensor::norm2(&g) as f32;
        prop_assert!(
            m.dequantize().iter().all(|v| v.abs() <= norm * 1.0001),
            "qsgd magnitude exceeds ||g||"
        );
        Ok(())
    });
}

#[test]
fn sparse_roundtrip_and_support() {
    Prop::new().check("sparse wire + support", |rng| {
        let p = 1 + rng.below(2000) as usize;
        let keep = rng.uniform_range(0.05, 1.0);
        let g = rand_vec(rng, p, 1.0);
        let s = Sparsifier::new(keep);
        let m = s.sparsify(&g, rng);
        let decoded = SparseMessage::decode(&m.encode(), p).map_err(|e| e.to_string())?;
        prop_assert!(decoded == m, "sparse roundtrip");
        // support is a subset of nonzero coordinates of g
        let d = m.densify();
        for (i, &v) in d.iter().enumerate() {
            if v != 0.0 {
                prop_assert!(g[i] != 0.0, "phantom coordinate {i}");
                prop_assert!(v.signum() == g[i].signum(), "sign flip at {i}");
            }
        }
        Ok(())
    });
}

/// One random payload of each variant from the same gradient scale.
fn random_payloads(rng: &mut Rng, p: usize) -> Vec<Payload> {
    let scale = 10f64.powf(rng.uniform_range(-2.0, 2.0));
    let g = rand_vec(rng, p, scale);
    let qp = rand_vec(rng, p, scale);
    let bits = 1 + rng.below(8) as u32;
    let (qi, _) = InnovationQuantizer::new(bits).quantize(&g, &qp);
    let qsgd = QsgdQuantizer::new(bits).quantize(&g, rng);
    let sparse = Sparsifier::new(rng.uniform_range(0.05, 1.0)).sparsify(&g, rng);
    let sign = SignEfCompressor::new(p).compress(&g);
    vec![
        Payload::Dense(g),
        Payload::Innovation(qi),
        Payload::Qsgd(qsgd),
        Payload::Sparse(sparse),
        Payload::Sign(sign),
    ]
}

#[test]
fn every_payload_variant_survives_the_wire_exactly() {
    // the invariant the lazy mirror consistency (and therefore the whole
    // aggregate identity) rests on: what the worker built is exactly what
    // the server decodes
    Prop::new().check("payload through_wire == identity", |rng| {
        let p = 1 + rng.below(1500) as usize;
        for payload in random_payloads(rng, p) {
            let received = payload
                .clone()
                .through_wire()
                .map_err(|e| e.to_string())?;
            prop_assert!(
                received == payload,
                "wire roundtrip changed a {payload:?}"
            );
        }
        Ok(())
    });
}

#[test]
fn wire_bits_equals_physically_serialized_size() {
    // the bit counters the sequential wire phase charges must equal the
    // size of the bytes that would actually cross the wire (padded to
    // whole bytes for the codec formats; dense payloads are raw IEEE754)
    Prop::new().check("wire_bits == serialized size", |rng| {
        let p = 1 + rng.below(1500) as usize;
        for payload in random_payloads(rng, p) {
            let declared = payload.wire_bits();
            let serialized_bytes: Option<usize> = match &payload {
                Payload::Dense(v) => {
                    // IEEE bits pass through unencoded: exactly 32 per coord
                    prop_assert!(declared == 32 * v.len(), "dense bits");
                    None
                }
                Payload::Innovation(m) => Some(m.encode().len()),
                Payload::Qsgd(m) => Some(m.encode().len()),
                Payload::Sparse(m) => Some(m.encode().len()),
                Payload::Sign(m) => Some(m.encode().len()),
            };
            if let Some(bytes) = serialized_bytes {
                prop_assert!(
                    bytes == declared.div_ceil(8),
                    "declared {declared} bits but serialized {bytes} bytes"
                );
            }
        }
        Ok(())
    });
}

#[test]
fn quantize_is_deterministic() {
    Prop::new().check("same input -> same message", |rng| {
        let p = 1 + rng.below(300) as usize;
        let g = rand_vec(rng, p, 1.0);
        let qp = rand_vec(rng, p, 1.0);
        let q = InnovationQuantizer::new(3);
        let (a, _) = q.quantize(&g, &qp);
        let (b, _) = q.quantize(&g, &qp);
        prop_assert!(a == b, "nondeterministic quantization");
        Ok(())
    });
}
