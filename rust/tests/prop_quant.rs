//! Property tests for the quantization codecs (paper §2.1 guarantees) and
//! the [`Payload`] wire invariants the trainer's sequential wire phase
//! relies on: every payload survives the physical encode/decode roundtrip
//! exactly, and `wire_bits()` equals the physically serialized size.

use laq::comm::{LatencyModel, Network, Payload};
use laq::prop_assert;
use laq::quant::innovation::{InnovationQuantizer, QuantizedInnovation};
use laq::quant::qsgd::{QsgdMessage, QsgdQuantizer};
use laq::quant::schedule::{
    BitSchedule, FixedBits, InnovationAdaptive, RoundDecay, WorkerBitState,
};
use laq::quant::signef::SignEfCompressor;
use laq::quant::sparsify::{SparseMessage, Sparsifier};
use laq::util::prop::Prop;
use laq::util::rng::Rng;
use laq::util::bitio::BitWriter;
use laq::util::tensor::norm_inf_diff;

fn rand_vec(rng: &mut Rng, p: usize, scale: f64) -> Vec<f32> {
    (0..p).map(|_| (rng.normal() * scale) as f32).collect()
}

#[test]
fn innovation_roundtrip_is_bit_exact() {
    Prop::new().check("innovation wire roundtrip", |rng| {
        let p = 1 + rng.below(3000) as usize;
        let bits = 1 + rng.below(8) as u32;
        let scale = 10f64.powf(rng.uniform_range(-4.0, 4.0));
        let g = rand_vec(rng, p, scale);
        let qp = rand_vec(rng, p, scale);
        let q = InnovationQuantizer::new(bits);
        let (qi, _) = q.quantize(&g, &qp);
        let decoded = QuantizedInnovation::decode(&qi.encode(), bits, p)
            .map_err(|e| e.to_string())?;
        prop_assert!(decoded == qi, "roundtrip mismatch p={p} bits={bits}");
        prop_assert!(
            qi.wire_bits() == 32 + bits as usize * p,
            "wire bits formula"
        );
        Ok(())
    });
}

#[test]
fn innovation_error_bounded_by_tau_r() {
    Prop::new().check("||eps||_inf <= tau R", |rng| {
        let p = 1 + rng.below(2000) as usize;
        let bits = 1 + rng.below(8) as u32;
        let g = rand_vec(rng, p, 1.0);
        let qp = rand_vec(rng, p, 1.0);
        let q = InnovationQuantizer::new(bits);
        let (qi, q_new) = q.quantize(&g, &qp);
        let tau = q.tau() as f32;
        let err = norm_inf_diff(&g, &q_new);
        prop_assert!(
            err <= tau * qi.radius * (1.0 + 1e-5) + 1e-30,
            "err {err} > tau*R {}",
            tau * qi.radius
        );
        Ok(())
    });
}

#[test]
fn innovation_codes_fit_bit_width() {
    Prop::new().check("codes in [0, 2^b)", |rng| {
        let p = 1 + rng.below(500) as usize;
        let bits = 1 + rng.below(8) as u32;
        let g = rand_vec(rng, p, 3.0);
        let qp = rand_vec(rng, p, 3.0);
        let (qi, _) = InnovationQuantizer::new(bits).quantize(&g, &qp);
        let max = (1u32 << bits) - 1;
        prop_assert!(
            qi.codes.iter().all(|&c| c <= max),
            "code exceeds width"
        );
        Ok(())
    });
}

#[test]
fn server_reconstruction_equals_worker() {
    // the mirror-consistency property the whole algorithm rests on,
    // through the PHYSICAL wire format
    Prop::new().check("dequantize(encode(quantize)) == worker view", |rng| {
        let p = 1 + rng.below(1000) as usize;
        let bits = 1 + rng.below(8) as u32;
        let q = InnovationQuantizer::new(bits);
        let mut q_prev = rand_vec(rng, p, 1.0);
        // several rounds of drift
        for _ in 0..4 {
            let g = rand_vec(rng, p, 1.0);
            let (qi, q_new_worker) = q.quantize(&g, &q_prev);
            let wire = QuantizedInnovation::decode(&qi.encode(), bits, p)
                .map_err(|e| e.to_string())?;
            let q_new_server = q.dequantize(&wire, &q_prev);
            prop_assert!(
                q_new_worker == q_new_server,
                "mirror drift at p={p} bits={bits}"
            );
            q_prev = q_new_worker;
        }
        Ok(())
    });
}

#[test]
fn qsgd_roundtrip_and_norm_bound() {
    Prop::new().check("qsgd wire + bound", |rng| {
        let p = 1 + rng.below(1000) as usize;
        let bits = 1 + rng.below(8) as u32;
        let g = rand_vec(rng, p, 2.0);
        let q = QsgdQuantizer::new(bits);
        let m = q.quantize(&g, rng);
        let decoded =
            QsgdMessage::decode(&m.encode(), bits, p).map_err(|e| e.to_string())?;
        prop_assert!(decoded == m, "qsgd roundtrip");
        let norm = laq::util::tensor::norm2(&g) as f32;
        prop_assert!(
            m.dequantize().iter().all(|v| v.abs() <= norm * 1.0001),
            "qsgd magnitude exceeds ||g||"
        );
        Ok(())
    });
}

#[test]
fn sparse_roundtrip_and_support() {
    Prop::new().check("sparse wire + support", |rng| {
        let p = 1 + rng.below(2000) as usize;
        let keep = rng.uniform_range(0.05, 1.0);
        let g = rand_vec(rng, p, 1.0);
        let s = Sparsifier::new(keep);
        let m = s.sparsify(&g, rng);
        let decoded = SparseMessage::decode(&m.encode(), p).map_err(|e| e.to_string())?;
        prop_assert!(decoded == m, "sparse roundtrip");
        // support is a subset of nonzero coordinates of g
        let d = m.densify();
        for (i, &v) in d.iter().enumerate() {
            if v != 0.0 {
                prop_assert!(g[i] != 0.0, "phantom coordinate {i}");
                prop_assert!(v.signum() == g[i].signum(), "sign flip at {i}");
            }
        }
        Ok(())
    });
}

/// One random payload of each variant from the same gradient scale.
fn random_payloads(rng: &mut Rng, p: usize) -> Vec<Payload> {
    let scale = 10f64.powf(rng.uniform_range(-2.0, 2.0));
    let g = rand_vec(rng, p, scale);
    let qp = rand_vec(rng, p, scale);
    let bits = 1 + rng.below(8) as u32;
    let (qi, _) = InnovationQuantizer::new(bits).quantize(&g, &qp);
    let qsgd = QsgdQuantizer::new(bits).quantize(&g, rng);
    let sparse = Sparsifier::new(rng.uniform_range(0.05, 1.0)).sparsify(&g, rng);
    let sign = SignEfCompressor::new(p).compress(&g);
    vec![
        Payload::Dense(g),
        Payload::Innovation(qi),
        Payload::Qsgd(qsgd),
        Payload::Sparse(sparse),
        Payload::Sign(sign),
    ]
}

#[test]
fn every_payload_variant_survives_the_wire_exactly() {
    // the invariant the lazy mirror consistency (and therefore the whole
    // aggregate identity) rests on: what the worker built is exactly what
    // the server decodes
    Prop::new().check("payload through_wire == identity", |rng| {
        let p = 1 + rng.below(1500) as usize;
        for payload in random_payloads(rng, p) {
            let received = payload
                .clone()
                .through_wire()
                .map_err(|e| e.to_string())?;
            prop_assert!(
                received == payload,
                "wire roundtrip changed a {payload:?}"
            );
        }
        Ok(())
    });
}

#[test]
fn wire_bits_equals_physically_serialized_size() {
    // the bit counters the sequential wire phase charges must equal the
    // size of the bytes that would actually cross the wire (padded to
    // whole bytes for the codec formats; dense payloads are raw IEEE754)
    Prop::new().check("wire_bits == serialized size", |rng| {
        let p = 1 + rng.below(1500) as usize;
        for payload in random_payloads(rng, p) {
            let declared = payload.wire_bits();
            let serialized_bytes: Option<usize> = match &payload {
                Payload::Dense(v) => {
                    // IEEE bits pass through unencoded: exactly 32 per coord
                    prop_assert!(declared == 32 * v.len(), "dense bits");
                    None
                }
                Payload::Innovation(m) => Some(m.encode().len()),
                Payload::Qsgd(m) => Some(m.encode().len()),
                Payload::Sparse(m) => Some(m.encode().len()),
                Payload::Sign(m) => Some(m.encode().len()),
            };
            if let Some(bytes) = serialized_bytes {
                prop_assert!(
                    bytes == declared.div_ceil(8),
                    "declared {declared} bits but serialized {bytes} bytes"
                );
            }
        }
        Ok(())
    });
}

#[test]
fn framed_innovation_roundtrip_recovers_width_exactly() {
    // the self-describing layout adaptive bit schedules transmit: the
    // decoder must recover (radius, width, codes) bit-exactly from the
    // wire alone, and the framed size must be the fixed size + the
    // 8-bit width field
    Prop::new().check("framed innovation wire roundtrip", |rng| {
        let p = 1 + rng.below(2500) as usize;
        let bits = 1 + rng.below(16) as u32;
        let scale = 10f64.powf(rng.uniform_range(-3.0, 3.0));
        let g = rand_vec(rng, p, scale);
        let qp = rand_vec(rng, p, scale);
        let (qi, _) = InnovationQuantizer::new(bits).quantize(&g, &qp);
        prop_assert!(
            qi.wire_bits_framed() == qi.wire_bits() + 8,
            "framed size formula"
        );
        let bytes = qi.encode_framed();
        prop_assert!(
            bytes.len() == qi.wire_bits_framed().div_ceil(8),
            "framed serialized size"
        );
        let back =
            QuantizedInnovation::decode_framed(&bytes, p).map_err(|e| e.to_string())?;
        prop_assert!(back == qi, "framed roundtrip mismatch p={p} bits={bits}");
        Ok(())
    });
}

#[test]
fn every_bit_schedule_stays_in_range_and_is_a_pure_fold() {
    // for every policy: the chosen width is always inside
    // [bits_min, bits_max], is a pure function of (state, worker, round),
    // and identical observation streams fold to identical states — the
    // trainer's (seed, config)-purity contract at the policy level
    Prop::new().check("bit schedules: in-range + pure", |rng| {
        let bits_min = 1 + rng.below(8) as u32;
        let span = rng.below((16 - bits_min) as u64 + 1) as u32;
        let bits_max = bits_min + span;
        let policies: Vec<Box<dyn BitSchedule>> = vec![
            Box::new(FixedBits { bits: bits_min }),
            Box::new(RoundDecay::new(bits_min, bits_max)),
            Box::new(InnovationAdaptive { bits_min, bits_max }),
        ];
        for sched in &policies {
            let mut st = WorkerBitState::default();
            let mut st2 = WorkerBitState::default();
            for k in 0..120usize {
                let m = rng.below(8) as usize;
                let w = sched.width(&st, m, k);
                prop_assert!(
                    (sched.min_width()..=sched.max_width()).contains(&w),
                    "{}: width {w} outside [{}, {}]",
                    sched.name(),
                    sched.min_width(),
                    sched.max_width()
                );
                prop_assert!(
                    sched.width(&st, m, k) == w && sched.width(&st2, m, k) == w,
                    "{}: width not a pure function of (state, worker, round)",
                    sched.name()
                );
                // fold one identical observation into both state copies
                let lhs = rng.uniform_range(0.0, 10.0);
                let rhs = rng.uniform_range(0.0, 10.0);
                sched.observe(&mut st, lhs, rhs, lhs > rhs);
                sched.observe(&mut st2, lhs, rhs, lhs > rhs);
                prop_assert!(st == st2, "{}: state fold diverged", sched.name());
            }
        }
        Ok(())
    });
}

#[test]
fn downlink_mirror_recursion_round_trips_within_grid_resolution() {
    // the quantized θ broadcast is the uplink codec pointed the other
    // way: per round the coordinator quantizes θ against the shared
    // downlink mirror at some width w, the worker decodes the framed
    // message against ITS mirror copy, and both commit the wire
    // reconstruction.  Two properties carry the whole downlink design:
    // (a) lock-step — the worker's reconstruction is bit-identical to
    //     the coordinator's, at every width and across width changes;
    // (b) resolution — each round's view error obeys the §2.1 bound
    //     ‖θ − θ̂‖∞ ≤ τ(w)·R with τ(w) = 1/(2^w − 1), so the worker view
    //     tracks θ within the grid of whatever width the schedule chose.
    Prop::new().check("downlink mirror recursion", |rng| {
        let p = 1 + rng.below(2000) as usize;
        let scale = 10f64.powf(rng.uniform_range(-3.0, 3.0));
        let mut theta = rand_vec(rng, p, scale);
        let mut mirror_coord = vec![0.0f32; p]; // coordinator copy
        let mut mirror_worker = vec![0.0f32; p]; // worker copy
        // several rounds of θ drift under schedule-varying widths
        for round in 0..5 {
            let w = 2 + rng.below(7) as u32; // down_bits range [2, 8]
            let q = InnovationQuantizer::new(w);
            let (qi, view_coord) = q.quantize(&theta, &mirror_coord);
            let wire = QuantizedInnovation::decode_framed(&qi.encode_framed(), p)
                .map_err(|e| e.to_string())?;
            prop_assert!(wire.bits == w, "width lost on the downlink wire");
            let view_worker = q.dequantize(&wire, &mirror_worker);
            prop_assert!(
                view_coord == view_worker,
                "downlink mirror drift at p={p} w={w} round={round}"
            );
            let tau = q.tau() as f32;
            let err = norm_inf_diff(&theta, &view_worker);
            prop_assert!(
                err <= tau * qi.radius * (1.0 + 1e-5) + 1e-30,
                "downlink view error {err} > tau*R {} at w={w}",
                tau * qi.radius
            );
            mirror_coord = view_coord;
            mirror_worker = view_worker;
            // the server moves θ before the next broadcast
            let step = rand_vec(rng, p, scale * 0.1);
            for (t, d) in theta.iter_mut().zip(&step) {
                *t += d;
            }
        }
        Ok(())
    });
}

#[test]
fn truncated_frames_surface_as_decode_errors_never_panics() {
    // a faulty transport can hand the decoder any prefix of a valid
    // frame; every strict prefix must die with a codec error — no panic
    // and no silent zero-fill of the missing codes — in BOTH layouts
    Prop::new().check("every strict prefix errors", |rng| {
        let p = 1 + rng.below(200) as usize;
        let bits = 1 + rng.below(16) as u32;
        let g = rand_vec(rng, p, 1.0);
        let qp = rand_vec(rng, p, 1.0);
        let (qi, _) = InnovationQuantizer::new(bits).quantize(&g, &qp);

        let fixed = qi.encode();
        prop_assert!(
            QuantizedInnovation::decode(&fixed, bits, p).is_ok(),
            "full fixed-layout frame must decode"
        );
        for cut in 0..fixed.len() {
            prop_assert!(
                QuantizedInnovation::decode(&fixed[..cut], bits, p).is_err(),
                "fixed-layout prefix of {cut}/{} bytes decoded silently",
                fixed.len()
            );
        }

        let framed = qi.encode_framed();
        prop_assert!(
            QuantizedInnovation::decode_framed(&framed, p).is_ok(),
            "full framed frame must decode"
        );
        for cut in 0..framed.len() {
            prop_assert!(
                QuantizedInnovation::decode_framed(&framed[..cut], p).is_err(),
                "framed prefix of {cut}/{} bytes decoded silently",
                framed.len()
            );
        }
        Ok(())
    });
}

#[test]
fn damaged_framed_width_field_is_rejected() {
    // byte 4 of the framed layout is the self-describing width field;
    // 0, 255 and the bitwise complement of any legal width all fall
    // outside 1..=16 and must be rejected before the decoder sizes the
    // codes section from the damaged value
    Prop::new().check("width byte damage -> Err", |rng| {
        let p = 1 + rng.below(500) as usize;
        let bits = 1 + rng.below(16) as u32;
        let g = rand_vec(rng, p, 1.0);
        let qp = rand_vec(rng, p, 1.0);
        let (qi, _) = InnovationQuantizer::new(bits).quantize(&g, &qp);
        let mut bytes = qi.encode_framed();
        let orig = bytes[4];
        for bad in [0x00u8, 0xFF, orig ^ 0xFF] {
            bytes[4] = bad;
            prop_assert!(
                QuantizedInnovation::decode_framed(&bytes, p).is_err(),
                "width byte {bad:#04x} accepted (orig {orig:#04x})"
            );
        }
        bytes[4] = orig;
        let restored =
            QuantizedInnovation::decode_framed(&bytes, p).map_err(|e| e.to_string())?;
        prop_assert!(restored == qi, "restored frame must decode to the original");
        Ok(())
    });
}

#[test]
fn non_finite_wire_radius_is_rejected_in_both_layouts() {
    // a NaN or ±inf radius would multiply into every reconstructed
    // coordinate of the server mirror and from there into θ; both
    // decoders must kill it at the header, never return it
    Prop::new().check("non-finite radius -> Err", |rng| {
        let p = 1 + rng.below(300) as usize;
        let bits = 1 + rng.below(8) as u32;
        let g = rand_vec(rng, p, 1.0);
        let qp = rand_vec(rng, p, 1.0);
        let (qi, _) = InnovationQuantizer::new(bits).quantize(&g, &qp);
        for bad in [f32::NAN, f32::INFINITY, f32::NEG_INFINITY] {
            let mut damaged = qi.clone();
            damaged.radius = bad;
            prop_assert!(
                QuantizedInnovation::decode(&damaged.encode(), bits, p).is_err(),
                "fixed layout accepted radius {bad}"
            );
            prop_assert!(
                QuantizedInnovation::decode_framed(&damaged.encode_framed(), p)
                    .is_err(),
                "framed layout accepted radius {bad}"
            );
        }
        Ok(())
    });
}

#[test]
fn tiled_bitio_twins_match_scalar_on_random_streams() {
    // the kernel-twin contract at the property level: for ANY width,
    // code stream and writer/reader misalignment, the tiled pack/unpack
    // paths produce byte-identical buffers and identical codes to the
    // scalar reference (the differential harness pins fixed shapes;
    // this sweeps the space)
    use laq::util::bitio::{
        pack_codes_scalar, pack_codes_tiled, unpack_codes_into_scalar,
        unpack_codes_into_tiled, BitReader,
    };
    Prop::new().check("tiled bitio == scalar bitio", |rng| {
        let p = rng.below(600) as usize;
        let bits = 1 + rng.below(16) as u32;
        let pre = rng.below(8) as u32;
        let mask = (1u64 << bits) - 1;
        let codes: Vec<u32> = (0..p).map(|_| (rng.next_u64() & mask) as u32).collect();

        let mut ws = BitWriter::new();
        let mut wt = BitWriter::new();
        if pre > 0 {
            let filler = rng.next_u64() & ((1 << pre) - 1);
            ws.write(filler, pre);
            wt.write(filler, pre);
        }
        pack_codes_scalar(&codes, bits, &mut ws);
        pack_codes_tiled(&codes, bits, &mut wt);
        prop_assert!(
            ws.as_bytes() == wt.as_bytes() && ws.len_bits() == wt.len_bits(),
            "pack drift p={p} bits={bits} pre={pre}"
        );

        let bytes = ws.into_bytes();
        let mut rs = BitReader::new(&bytes);
        let mut rt = BitReader::new(&bytes);
        if pre > 0 {
            rs.read(pre);
            rt.read(pre);
        }
        let (mut out_s, mut out_t) = (Vec::new(), Vec::new());
        let oks = unpack_codes_into_scalar(&mut rs, bits, p, &mut out_s);
        let okt = unpack_codes_into_tiled(&mut rt, bits, p, &mut out_t);
        prop_assert!(oks.is_some() && okt.is_some(), "well-formed stream rejected");
        prop_assert!(out_s == codes && out_t == codes, "unpack drift p={p} bits={bits}");
        Ok(())
    });
}

#[test]
fn truncated_streams_fail_both_bitio_twins_identically() {
    // the adversarial-prefix recipe applied at the twin level: every
    // strict byte prefix of a packed stream must be rejected by BOTH
    // unpack twins (None, never panic, never zero-fill) — so the
    // decoders surface Error::Codec whichever kernel mode is live
    use laq::util::bitio::{
        pack_codes_scalar, unpack_codes_into_scalar, unpack_codes_into_tiled, BitReader,
    };
    Prop::new().check("every prefix -> None in both twins", |rng| {
        let p = 1 + rng.below(120) as usize;
        let bits = 1 + rng.below(16) as u32;
        let mask = (1u64 << bits) - 1;
        let codes: Vec<u32> = (0..p).map(|_| (rng.next_u64() & mask) as u32).collect();
        let mut w = BitWriter::new();
        pack_codes_scalar(&codes, bits, &mut w);
        let bytes = w.into_bytes();
        for cut in 0..bytes.len() {
            let mut out = Vec::new();
            prop_assert!(
                unpack_codes_into_scalar(&mut BitReader::new(&bytes[..cut]), bits, p, &mut out)
                    .is_none(),
                "scalar twin accepted a {cut}/{}-byte prefix",
                bytes.len()
            );
            prop_assert!(
                unpack_codes_into_tiled(&mut BitReader::new(&bytes[..cut]), bits, p, &mut out)
                    .is_none(),
                "tiled twin accepted a {cut}/{}-byte prefix",
                bytes.len()
            );
        }
        Ok(())
    });
}

#[test]
fn quantize_is_deterministic() {
    Prop::new().check("same input -> same message", |rng| {
        let p = 1 + rng.below(300) as usize;
        let g = rand_vec(rng, p, 1.0);
        let qp = rand_vec(rng, p, 1.0);
        let q = InnovationQuantizer::new(3);
        let (a, _) = q.quantize(&g, &qp);
        let (b, _) = q.quantize(&g, &qp);
        prop_assert!(a == b, "nondeterministic quantization");
        Ok(())
    });
}

#[test]
fn network_billing_matches_framed_encoder_output() {
    // The billing entry points the trainer charges through —
    // `Network::payload_wire_bits` (uplink, per session framing) and
    // `Network::downlink_wire_bits` (quantized downlink) — must equal
    // the bit count the framed encoder physically produces, with the
    // wire byte buffer exactly ⌈bits/8⌉ long.  The TCP transport bills
    // 8 bits per byte actually written, so any drift here would make
    // `transport = sim` and `transport = tcp` disagree on cost.
    Prop::new().check("billing == encoder output", |rng| {
        let p = 1 + rng.below(1500) as usize;
        let unframed = Network::new(1, LatencyModel::default());
        let mut framed = Network::new(1, LatencyModel::default());
        framed.set_framed(true);
        for payload in random_payloads(rng, p) {
            // fixed-framing session: billing is the payload's own size
            prop_assert!(
                unframed.payload_wire_bits(&payload) == payload.wire_bits(),
                "unframed session billed differently from the payload"
            );
            match &payload {
                Payload::Innovation(qi) => {
                    let mut w = BitWriter::with_capacity_bits(qi.wire_bits_framed());
                    qi.encode_framed_into(&mut w);
                    let billed = framed.payload_wire_bits(&payload);
                    prop_assert!(
                        billed == w.len_bits(),
                        "framed uplink billed {billed} bits, encoder wrote {}",
                        w.len_bits()
                    );
                    prop_assert!(
                        w.as_bytes().len() == billed.div_ceil(8),
                        "framed buffer {} bytes != ceil({billed}/8)",
                        w.as_bytes().len()
                    );
                    prop_assert!(
                        Network::downlink_wire_bits(&payload) == w.len_bits(),
                        "downlink billed differently from the framed encoder"
                    );
                }
                other => {
                    // only innovations change layout with the session
                    // framing; everything else bills its fixed size
                    prop_assert!(
                        framed.payload_wire_bits(&payload) == other.wire_bits(),
                        "framed session changed a non-innovation bill"
                    );
                    prop_assert!(
                        Network::downlink_wire_bits(&payload) == other.wire_bits(),
                        "downlink changed a non-innovation bill"
                    );
                }
            }
        }
        // the exact-downlink helper (what the TCP broadcast bills per
        // coordinate) is the dense payload's IEEE754 size
        prop_assert!(
            Network::downlink_dense_bits(p) == 32 * p,
            "dense downlink is not raw IEEE754"
        );
        Ok(())
    });
}
