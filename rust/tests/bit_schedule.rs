//! Contracts of the adaptive per-worker bit-width ("dial-a-bit")
//! subsystem (`cfg.bit_schedule`, see `laq::quant::schedule`):
//!
//! * **fixed bit-identity** — `bit_schedule = fixed` is the paper's
//!   constant-width behavior and must never drift (the golden
//!   fingerprints in `rust/tests/wire_equivalence.rs` pin it across
//!   PRs); an adaptive kind whose range collapses (`bits_min ==
//!   bits_max`) degenerates **bit-identically** to fixed at that width —
//!   same arithmetic, same wire layout, same accounting.
//! * **range discipline** — every chosen width lies in
//!   `[bits_min, bits_max]`, whatever the policy.
//! * **per-seed reproducibility** — adaptive traces are pure functions
//!   of (seed, config): identical across reruns and across every
//!   (threads, shards) combination, under the sync, async and
//!   async-cross wire phases alike.
//! * **the bits-for-accuracy win** — on strongly convex logreg, the
//!   `innovation` policy ends within the sync final-loss tolerance of a
//!   fixed-width run while uploading strictly fewer total bits at the
//!   same round count (the headline acceptance criterion; the
//!   `trainer_bits` bench group records the same sweep in
//!   `BENCH_trainer.json`).
//! * **1-bit floor** — the width floor round-trips the wire exactly and
//!   trains.
//! * **validation** — inverted/out-of-range `[bits_min, bits_max]` are
//!   rejected from TOML and the CLI path's `validate()` alike.
//! * **v4 checkpoint resume** — schedule kind + per-worker fold state
//!   persist, and a mid-run resume replays the remaining trace
//!   bit-for-bit.

use laq::config::{Algo, BitScheduleKind, RunCfg, WireMode};

fn cfg_for(
    algo: Algo,
    kind: BitScheduleKind,
    bits_min: u32,
    bits_max: u32,
    threads: usize,
    shards: usize,
) -> RunCfg {
    let mut c = RunCfg::paper_logreg(algo);
    // mnist-like keeps p = 7840 (8 coordinate blocks ⇒ real shard plans);
    // tiny row counts keep the suite fast
    c.data.n_train = 240;
    c.data.n_test = 60;
    c.workers = 4;
    c.iters = 40;
    c.batch = 40;
    c.record_every = 1;
    c.threads = threads;
    c.server_shards = shards;
    // pin the wire schedule and downlink mode regardless of the CI
    // env-matrix defaults; the async purity test below re-sets the wire
    // explicitly, and `rust/tests/downlink.rs` owns the quantized-downlink
    // contracts
    c.wire_mode = WireMode::Sync;
    c.staleness_bound = 0;
    c.downlink = laq::config::DownlinkMode::Exact;
    c.bit_schedule = kind;
    c.bits_min = bits_min;
    c.bits_max = bits_max;
    if algo.is_stochastic() {
        c.alpha = 0.01;
    }
    c
}

/// Everything observable about a run, collected per iteration.
#[derive(Debug, PartialEq)]
struct Trace {
    // (loss, grad_norm_sq, bits, uploads, max_eps_sq) per step — f64
    // compared exactly: the contracts here are bit-for-bit unless a
    // test says otherwise
    steps: Vec<(f64, f64, u64, usize, f64)>,
    rounds: u64,
    bits: u64,
    sim_time: f64,
    per_worker_rounds: Vec<u64>,
    clocks: Vec<usize>,
    theta: Vec<f32>,
    /// per-step snapshot of the schedule's chosen widths
    widths: Vec<Vec<u32>>,
}

fn run_trace(cfg: &RunCfg) -> Trace {
    let mut t = laq::algo::build_native(cfg).unwrap();
    let mut steps = Vec::with_capacity(cfg.iters);
    let mut widths = Vec::with_capacity(cfg.iters);
    for _ in 0..cfg.iters {
        let s = t.step().unwrap();
        steps.push((s.loss, s.grad_norm_sq, s.bits, s.uploads, s.max_eps_sq));
        widths.push(t.bit_widths().to_vec());
    }
    Trace {
        steps,
        rounds: t.net.uplink_rounds(),
        bits: t.net.uplink_bits(),
        sim_time: t.net.sim_time(),
        per_worker_rounds: t.net.per_worker_rounds().to_vec(),
        clocks: t.clocks(),
        theta: t.theta().to_vec(),
        widths,
    }
}

#[test]
fn collapsed_adaptive_ranges_degenerate_bit_identically_to_fixed() {
    // bits_min == bits_max: the schedule normalizes to fixed at that
    // width — same quantization, same (unframed) wire layout, same
    // accounting, for every adaptive kind and both lazy codec families
    for algo in [Algo::Laq, Algo::Qgd, Algo::Slaq] {
        let mut fixed = cfg_for(algo, BitScheduleKind::Fixed, 2, 8, 1, 1);
        fixed.bits = 3;
        let reference = run_trace(&fixed);
        for kind in [BitScheduleKind::Innovation, BitScheduleKind::RoundDecay] {
            let degenerate = cfg_for(algo, kind, 3, 3, 1, 1);
            let t = run_trace(&degenerate);
            assert_eq!(
                reference,
                t,
                "{}: {} with bits_min == bits_max == 3 diverged from fixed b=3",
                algo.name(),
                kind.name()
            );
        }
    }
}

#[test]
fn chosen_widths_stay_inside_the_configured_range() {
    for (kind, lo, hi) in [
        (BitScheduleKind::Innovation, 1u32, 8u32),
        (BitScheduleKind::RoundDecay, 2, 5),
    ] {
        let t = run_trace(&cfg_for(Algo::Laq, kind, lo, hi, 1, 1));
        for (k, ws) in t.widths.iter().enumerate() {
            for (m, &w) in ws.iter().enumerate() {
                assert!(
                    (lo..=hi).contains(&w),
                    "{}: round {k} worker {m} width {w} outside [{lo}, {hi}]",
                    kind.name()
                );
            }
        }
    }
    // round-decay is additionally monotone non-increasing per worker
    let t = run_trace(&cfg_for(Algo::Laq, BitScheduleKind::RoundDecay, 2, 5, 1, 1));
    for m in 0..4 {
        let mut prev = u32::MAX;
        for (k, ws) in t.widths.iter().enumerate() {
            assert!(ws[m] <= prev, "round-decay width rose at round {k} worker {m}");
            prev = ws[m];
        }
    }
}

#[test]
fn adaptive_trace_is_reproducible_per_seed_across_threads_and_shards() {
    for algo in [Algo::Laq, Algo::Slaq] {
        let base = run_trace(&cfg_for(algo, BitScheduleKind::Innovation, 2, 4, 1, 1));
        for (threads, shards) in [(1usize, 7usize), (4, 1), (4, 7)] {
            let t = run_trace(&cfg_for(
                algo,
                BitScheduleKind::Innovation,
                2,
                4,
                threads,
                shards,
            ));
            assert_eq!(
                base,
                t,
                "{}: adaptive threads={threads} shards={shards} not reproducible",
                algo.name()
            );
        }
        let again = run_trace(&cfg_for(algo, BitScheduleKind::Innovation, 2, 4, 4, 7));
        assert_eq!(base, again, "{}: adaptive rerun diverged", algo.name());
    }
}

#[test]
fn adaptive_widths_compose_with_the_async_wire_phases() {
    // the width fold lives on the coordinator in index order, so the
    // reproducibility contract must survive the overlapped wire phases
    // (including cross-round parking, where an upload lands at the width
    // it was quantized with rounds earlier)
    for (wire, staleness) in [(WireMode::Async, 2usize), (WireMode::AsyncCross, 2)] {
        let mut base_cfg = cfg_for(Algo::Laq, BitScheduleKind::Innovation, 2, 4, 1, 1);
        base_cfg.wire_mode = wire;
        base_cfg.staleness_bound = staleness;
        let base = run_trace(&base_cfg);
        for (threads, shards) in [(4usize, 1usize), (4, 7)] {
            let mut cfg = base_cfg.clone();
            cfg.threads = threads;
            cfg.server_shards = shards;
            let t = run_trace(&cfg);
            assert_eq!(
                base,
                t,
                "{} adaptive threads={threads} shards={shards} not reproducible",
                wire.name()
            );
        }
        // staleness actually deferred something under async-cross — the
        // adaptive landing-width path was genuinely exercised
        if wire == WireMode::AsyncCross {
            let mut t = laq::algo::build_native(&base_cfg).unwrap();
            for _ in 0..base_cfg.iters {
                t.step().unwrap();
            }
            let (max_lag, deferred) = t.staleness_stats();
            assert!(deferred > 0, "async-cross adaptive run never deferred");
            assert!(max_lag <= staleness);
        }
    }
}

#[test]
fn innovation_schedule_cuts_bits_at_matched_convergence() {
    // the headline acceptance criterion: at the same round count on
    // strongly convex logreg, the innovation policy ends within the sync
    // final-loss tolerance while uploading strictly fewer total bits
    // than fixed b=3 (each full-width framed message costs 8 bits more
    // than fixed, so the win must come from genuinely narrower uploads)
    let mut fixed = cfg_for(Algo::Laq, BitScheduleKind::Fixed, 2, 3, 1, 1);
    fixed.bits = 3;
    fixed.iters = 240;
    let f = run_trace(&fixed);

    let mut adaptive = cfg_for(Algo::Laq, BitScheduleKind::Innovation, 2, 3, 1, 1);
    adaptive.bits = 3;
    adaptive.iters = 240;
    let a = run_trace(&adaptive);

    // same iteration horizon; the schedule must have dialed below max at
    // least once (otherwise the comparison is vacuous)
    assert_eq!(f.steps.len(), a.steps.len());
    let min_width = a.widths.iter().flatten().copied().min().unwrap();
    assert!(min_width < 3, "schedule never dialed below the ceiling");

    assert!(
        a.bits < f.bits,
        "adaptive uploaded {} bits vs fixed {} — no saving",
        a.bits,
        f.bits
    );

    let first = f.steps.first().unwrap().0;
    let lf = f.steps.last().unwrap().0;
    let la = a.steps.last().unwrap().0;
    assert!(lf < 0.8 * first, "fixed run did not contract ({first} -> {lf})");
    assert!(la < 0.8 * first, "adaptive run did not contract ({first} -> {la})");
    assert!(
        (la - lf).abs() <= 0.05 * lf.abs().max(1e-9),
        "adaptive final loss {la} strays from fixed {lf} beyond 5%"
    );
}

#[test]
fn one_bit_floor_trains_and_round_trips() {
    // bits_min == bits_max == 1 degenerates to fixed 1-bit — the floor
    // must survive the full trainer loop (quantize → wire → absorb →
    // mirror commit) with finite losses and exact mirror lock-step
    let cfg = cfg_for(Algo::Laq, BitScheduleKind::Innovation, 1, 1, 1, 1);
    let mut t = laq::algo::build_native(&cfg).unwrap();
    for _ in 0..10 {
        let s = t.step().unwrap();
        assert!(s.loss.is_finite());
        assert!(t.bit_widths().iter().all(|&w| w == 1));
    }
    assert!(t.aggregate_drift() < 1e-3);
    for m in 0..t.n_workers() {
        assert_eq!(t.worker_mirror(m), t.server_mirror(m), "worker {m} mirror drift");
    }
    // a genuinely adaptive range reaching the 1-bit floor also trains
    // (round-decay 3 → 2 → 1: 32 warm rounds, first drop at 64, floor at
    // 96 — the first decay interval is still full-width)
    let mut cfg = cfg_for(Algo::Laq, BitScheduleKind::RoundDecay, 1, 3, 1, 1);
    cfg.iters = 100;
    let mut t = laq::algo::build_native(&cfg).unwrap();
    for _ in 0..cfg.iters {
        assert!(t.step().unwrap().loss.is_finite());
    }
    assert_eq!(
        t.bit_widths().iter().copied().max(),
        Some(1),
        "decay never hit the floor"
    );
}

#[test]
fn round_decay_pins_the_exact_warm_and_decay_step_sequence() {
    // regression guard for the historical `+1` off-by-one: the moment the
    // warm period ended, the old arithmetic charged one decay step
    // immediately, so the first drop landed at round `warm_rounds`
    // instead of `warm_rounds + decay_every` and every later step was one
    // interval early.  Pin the documented sequence exactly.
    use laq::quant::{BitSchedule, RoundDecay, WorkerBitState};
    let st = WorkerBitState::default();

    // default cadence (RoundDecay::new): 32 warm rounds at bits_max, the
    // first FULL interval also at bits_max, one bit per interval after
    let s = RoundDecay::new(2, 5);
    assert_eq!(s.width(&st, 0, 0), 5);
    assert_eq!(s.width(&st, 0, 31), 5);
    assert_eq!(s.width(&st, 0, 32), 5, "warm-period end must NOT drop (the +1 bug)");
    assert_eq!(s.width(&st, 0, 63), 5);
    assert_eq!(s.width(&st, 0, 64), 4, "first drop a full interval after warmup");
    assert_eq!(s.width(&st, 0, 95), 4);
    assert_eq!(s.width(&st, 0, 96), 3);
    assert_eq!(s.width(&st, 0, 128), 2);
    assert_eq!(s.width(&st, 0, 160), 2, "width fell through the floor");
    for k in 0..256 {
        let expect = if k < 64 {
            5
        } else {
            5u32.saturating_sub(((k - 32) / 32) as u32).max(2)
        };
        assert_eq!(s.width(&st, 0, k), expect, "round {k}");
    }

    // compact custom cadence: the whole width sequence, literally
    let s = RoundDecay { bits_min: 1, bits_max: 3, warm_rounds: 2, decay_every: 2 };
    let widths: Vec<u32> = (0..10).map(|k| s.width(&st, 0, k)).collect();
    assert_eq!(widths, vec![3, 3, 3, 3, 2, 2, 1, 1, 1, 1]);

    // the downlink seat defaults to the same rule — a shard index in the
    // worker slot must see the identical sequence
    for k in 0..10 {
        assert_eq!(s.downlink_width(&st, 5, k), s.width(&st, 5, k), "round {k}");
    }
}

#[test]
fn validation_rejects_bad_ranges_from_toml_and_validate() {
    // the CLI path funnels through the same RunCfg::validate()
    let mut c = RunCfg::paper_logreg(Algo::Laq);
    c.bit_schedule = BitScheduleKind::Innovation;
    c.bits_min = 5;
    c.bits_max = 3;
    assert!(c.validate().is_err(), "inverted range accepted");
    c.bits_min = 0;
    c.bits_max = 3;
    assert!(c.validate().is_err(), "zero bits_min accepted");
    c.bits_min = 2;
    c.bits_max = 17;
    assert!(c.validate().is_err(), "bits_max 17 accepted");

    let bad = "\n[run]\nbit_schedule = \"innovation\"\nbits_min = 5\nbits_max = 3\n";
    let mut c = RunCfg::paper_logreg(Algo::Laq);
    assert!(
        c.load_str_for_test(bad).is_err(),
        "TOML inverted range accepted"
    );
}

// `RunCfg::load_file` wants a path; parse the TOML through the same code
// path without touching disk.
trait LoadStr {
    fn load_str_for_test(&mut self, doc: &str) -> laq::Result<()>;
}

impl LoadStr for RunCfg {
    fn load_str_for_test(&mut self, doc: &str) -> laq::Result<()> {
        let parsed = laq::config::toml::parse(doc).map_err(|e| laq::Error::Config(e.to_string()))?;
        self.apply_json(&parsed)
    }
}

#[test]
fn checkpoint_v4_resumes_adaptive_runs_bit_exactly() {
    let dir = std::env::temp_dir().join("laq_bits_ckpt");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("mid.ckpt");

    let cfg = cfg_for(Algo::Laq, BitScheduleKind::Innovation, 2, 4, 1, 1);

    // uninterrupted reference run
    let mut straight = laq::algo::build_native(&cfg).unwrap();
    for _ in 0..30 {
        straight.step().unwrap();
    }

    let mut first = laq::algo::build_native(&cfg).unwrap();
    for _ in 0..15 {
        first.step().unwrap();
    }
    first.save_checkpoint(&path).unwrap();

    // resume on a trainer configured with the default fixed schedule —
    // the checkpoint's recorded policy + per-worker fold state must take
    // over (exactly like the wire schedule)
    let mut fixed_cfg = cfg_for(Algo::Laq, BitScheduleKind::Fixed, 2, 8, 4, 7);
    fixed_cfg.bits = 3;
    let mut resumed = laq::algo::build_native(&fixed_cfg).unwrap();
    resumed.load_checkpoint(&path).unwrap();
    assert_eq!(resumed.cfg.bit_schedule, BitScheduleKind::Innovation);
    assert_eq!((resumed.cfg.bits_min, resumed.cfg.bits_max), (2, 4));
    assert_eq!(resumed.bit_schedule_name(), "innovation");
    for _ in 0..15 {
        resumed.step().unwrap();
    }

    assert_eq!(straight.theta(), resumed.theta());
    assert_eq!(straight.bit_widths(), resumed.bit_widths());
    let _ = std::fs::remove_dir_all(&dir);
}
