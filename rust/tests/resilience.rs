//! Contracts of the self-healing coordinator (`cfg.resilience`):
//! fault-aware scheduling, retry/backoff, and quorum rounds layered on
//! the scenario engine.
//!
//! * **empty-resilience identity** — a config whose `[resilience]` table
//!   is absent or empty drives the exact pre-resilience trainer:
//!   bit-identical traces across the (threads, shards) grid under sync
//!   and async wire modes, faulted fleet included.  The
//!   `wire_equivalence` goldens (which predate the runtime) stay
//!   unchanged — `ci.sh` pins their hashes.
//! * **headline contract** — under the heavy-tail straggler fleet,
//!   resilience-on reaches the fault-free final loss within tolerance on
//!   strictly less `sim_time` and no more uplink bits than
//!   resilience-off.
//! * **purity** — every resilience decision (cadence verdicts, retry
//!   ladders, quorum clamps, health folds) is a pure function of
//!   (seed, config): identical across reruns and the thread/shard grid,
//!   under every wire mode.
//! * **quorum accounting** — under sync wire, the quorum clamp touches
//!   only the simulated clock: θ and the bit ledger are bit-identical to
//!   quorum-off, `sim_time` strictly smaller once a clamp fires.
//! * **checkpoint v6** — health state (EMAs, streaks, phases, demotion
//!   rounds) resumes bit-exactly across a save/load boundary placed
//!   after a demotion; a checkpoint carrying health state refuses to
//!   load into a resilience-less trainer.

use laq::algo::resilience::WorkerHealth;
use laq::config::{Algo, ResilienceCfg, RunCfg, WireMode, WorkerFaults};

fn cfg_for(algo: Algo, wire: WireMode, staleness: usize, threads: usize, shards: usize) -> RunCfg {
    let mut c = RunCfg::paper_logreg(algo);
    // mnist-like keeps p = 7840 (8 coordinate blocks ⇒ real shard plans);
    // tiny row counts keep the suite fast
    c.data.n_train = 240;
    c.data.n_test = 60;
    c.workers = 4;
    c.iters = 30;
    c.batch = 40;
    c.record_every = 1;
    c.threads = threads;
    c.server_shards = shards;
    c.wire_mode = wire;
    c.staleness_bound = staleness;
    c.downlink = laq::config::DownlinkMode::Exact;
    c
}

/// The heavy-tail straggler: Pareto α = 1.2 latency multiples with a
/// deadline at 3× — roughly a quarter of its wanted uploads miss, and
/// the ones that land each charge up to 2 extra message-times into the
/// simulated clock.
fn straggler_fleet() -> Vec<WorkerFaults> {
    vec![WorkerFaults {
        worker: 1,
        straggle_alpha: Some(1.2),
        deadline: 3.0,
        ..WorkerFaults::default()
    }]
}

/// The resilience policy under test: one effective miss demotes, reduced
/// cadence selects the worker every 4th round, and `restore_rounds` is
/// far beyond what a 60-round run can accumulate at that cadence — a
/// demoted worker stays demoted for the horizon.
fn healing_policy() -> ResilienceCfg {
    ResilienceCfg {
        cadence: 4,
        miss_threshold: 1,
        restore_rounds: 30,
        ..ResilienceCfg::default()
    }
}

/// Everything observable about a run, compared exactly for the identity
/// and purity contracts.
#[derive(Debug, PartialEq)]
struct Trace {
    steps: Vec<(f64, f64, u64, usize, f64)>,
    rounds: u64,
    bits: u64,
    down_bits: u64,
    sim_time: f64,
    per_worker_rounds: Vec<u64>,
    clocks: Vec<usize>,
    rejections: u64,
    stats: (u64, u64, u64),
    health: Vec<WorkerHealth>,
    theta: Vec<f32>,
}

fn run_trace(cfg: &RunCfg) -> Trace {
    let mut t = laq::algo::build_native(cfg).unwrap();
    let mut steps = Vec::with_capacity(cfg.iters);
    for _ in 0..cfg.iters {
        let s = t.step().unwrap();
        steps.push((s.loss, s.grad_norm_sq, s.bits, s.uploads, s.max_eps_sq));
    }
    let health = (0..cfg.workers).map(|m| *t.worker_health(m)).collect();
    Trace {
        steps,
        rounds: t.net.uplink_rounds(),
        bits: t.net.uplink_bits(),
        down_bits: t.net.downlink_bits(),
        sim_time: t.net.sim_time(),
        per_worker_rounds: t.net.per_worker_rounds().to_vec(),
        clocks: t.clocks(),
        rejections: t.scenario_rejections(),
        stats: t.resilience_stats(),
        health,
        theta: t.theta().to_vec(),
    }
}

#[test]
fn empty_resilience_section_is_bit_identical_across_the_grid() {
    // acceptance: an empty [resilience] table — whether absent or
    // present-but-empty in the TOML — drives the pre-resilience trainer
    // bit-for-bit, fault fleet included, at every grid point
    let toml = "[resilience]\n";
    for (wire, staleness) in [(WireMode::Sync, 0usize), (WireMode::Async, 2)] {
        let mut base_cfg = cfg_for(Algo::Laq, wire, staleness, 1, 1);
        base_cfg.scenario.workers = straggler_fleet();
        let base = run_trace(&base_cfg);
        for (threads, shards) in [(1usize, 7usize), (4, 1), (4, 7)] {
            let mut cfg = cfg_for(Algo::Laq, wire, staleness, threads, shards);
            cfg.scenario.workers = straggler_fleet();
            let j = laq::config::toml::parse(toml).unwrap();
            cfg.apply_json(&j).unwrap();
            assert!(
                cfg.resilience.is_empty(),
                "an empty [resilience] table must stay empty"
            );
            let t = run_trace(&cfg);
            assert_eq!(
                base, t,
                "empty resilience {wire:?} s={staleness} threads={threads} shards={shards} diverged"
            );
        }
    }
}

#[test]
fn self_healing_beats_resilience_off_under_heavy_tail_stragglers() {
    // THE headline contract (ISSUE 8): under the PR 7 heavy-tail
    // straggler fleet, resilience-on reaches the fault-free final loss
    // within tolerance on strictly less sim_time and no more uplink
    // bits than resilience-off.  The mechanism: the first missed
    // deadline demotes the straggler to a 4-round cadence, so three
    // quarters of its billed message-times and straggle excesses — and
    // all of its missed-deadline stalls — leave the critical path, while
    // the lazy aggregate carries its stale gradient exactly as LAQ
    // already does for criterion skips.
    let mut free_cfg = cfg_for(Algo::Laq, WireMode::Sync, 0, 1, 1);
    free_cfg.iters = 60;
    let mut off_cfg = free_cfg.clone();
    off_cfg.scenario.workers = straggler_fleet();
    let mut on_cfg = off_cfg.clone();
    on_cfg.resilience = healing_policy();
    on_cfg.validate().unwrap();

    let mut free = laq::algo::build_native(&free_cfg).unwrap();
    let mut off = laq::algo::build_native(&off_cfg).unwrap();
    let mut on = laq::algo::build_native(&on_cfg).unwrap();
    for _ in 0..free_cfg.iters {
        free.step().unwrap();
        off.step().unwrap();
        on.step().unwrap();
    }

    let (demotions, _, _) = on.resilience_stats();
    assert!(demotions >= 1, "the chronic straggler was never demoted");
    assert!(
        on.net.sim_time() < off.net.sim_time(),
        "resilience-on must cost strictly less sim_time: on={} off={}",
        on.net.sim_time(),
        off.net.sim_time()
    );
    assert!(
        on.net.uplink_bits() <= off.net.uplink_bits(),
        "resilience-on must cost no more uplink bits: on={} off={}",
        on.net.uplink_bits(),
        off.net.uplink_bits()
    );

    let (last_free, _) = free.eval_full().unwrap();
    let (last_on, _) = on.eval_full().unwrap();
    assert!(
        (last_on - last_free).abs() <= 0.25 * last_free.abs().max(1e-9),
        "self-healed final loss {last_on} too far from fault-free {last_free}"
    );
}

#[test]
fn resilience_decisions_are_a_pure_function_of_seed_and_config() {
    // every policy at once — cadence + retries + quorum (+ per-worker
    // staleness slack under async-cross) — reproduces bit-for-bit
    // across reruns and the {1,4}×{1,7} grid under every wire mode
    for (wire, staleness) in
        [(WireMode::Sync, 0usize), (WireMode::Async, 2), (WireMode::AsyncCross, 2)]
    {
        let policy = ResilienceCfg {
            cadence: 4,
            miss_threshold: 1,
            restore_rounds: 5,
            max_retries: 2,
            backoff_base: 1e-3,
            backoff_cap: 2e-3,
            quorum: 0.75,
            staleness_slack: if wire == WireMode::AsyncCross { 2 } else { 0 },
            ..ResilienceCfg::default()
        };
        let mut base_cfg = cfg_for(Algo::Laq, wire, staleness, 1, 1);
        base_cfg.scenario.workers = vec![
            WorkerFaults { worker: 0, corrupt_rate: 0.3, ..WorkerFaults::default() },
            WorkerFaults {
                worker: 1,
                straggle_alpha: Some(1.2),
                deadline: 3.0,
                ..WorkerFaults::default()
            },
            WorkerFaults {
                worker: 3,
                drop_from: Some(9),
                drop_until: Some(18),
                ..WorkerFaults::default()
            },
        ];
        base_cfg.resilience = policy.clone();
        base_cfg.validate().unwrap();
        let base = run_trace(&base_cfg);
        assert!(base.rounds > 0, "the healed fleet must still communicate");
        for (threads, shards) in [(1usize, 7usize), (4, 1), (4, 7)] {
            let mut cfg = cfg_for(Algo::Laq, wire, staleness, threads, shards);
            cfg.scenario.workers = base_cfg.scenario.workers.clone();
            cfg.resilience = policy.clone();
            let t = run_trace(&cfg);
            assert_eq!(
                base, t,
                "resilience {wire:?} s={staleness} threads={threads} shards={shards} not reproducible"
            );
        }
        let again = run_trace(&base_cfg);
        assert_eq!(base, again, "resilience {wire:?} rerun diverged");
    }
}

#[test]
fn retry_ladder_burns_billed_frames_and_salvages_corrupt_rounds() {
    // a corrupt-prone worker with two in-round retries: superseded
    // corrupt frames are billed AND rejected (they crossed the wire),
    // backoff lands in sim_time, and the salvage shows up as strictly
    // fewer final-verdict corruptions than the retry-less run
    let mut off_cfg = cfg_for(Algo::Laq, WireMode::Sync, 0, 1, 1);
    off_cfg.scenario.workers =
        vec![WorkerFaults { worker: 0, corrupt_rate: 0.5, ..WorkerFaults::default() }];
    let mut on_cfg = off_cfg.clone();
    on_cfg.resilience = ResilienceCfg {
        max_retries: 2,
        backoff_base: 1e-3,
        backoff_cap: 4e-3,
        ..ResilienceCfg::default()
    };
    on_cfg.validate().unwrap();

    let off = run_trace(&off_cfg);
    let on = run_trace(&on_cfg);
    assert!(off.rejections > 0, "corrupt_rate = 0.5 drew no corruption at all");
    let (_, retries, _) = on.stats;
    assert!(retries > 0, "a 0.5 corrupt rate never triggered the retry ladder");
    assert!(
        on.rejections > 0,
        "retry-superseded corrupt frames must still be billed + rejected"
    );
    assert!(
        on.sim_time > off.sim_time,
        "backoff waits must land in sim_time: on={} off={}",
        on.sim_time,
        off.sim_time
    );
    assert!(
        on.theta.iter().all(|x| x.is_finite()),
        "a corrupt frame slipped past the retry ladder into θ"
    );
}

#[test]
fn quorum_clamp_touches_only_the_simulated_clock_under_sync() {
    // quorum rounds with deadline-less stragglers: under sync wire the
    // clamp stops charging the slowest workers' straggle excess but
    // changes no upload decision — θ, the bit ledger, and every round
    // count are bit-identical to quorum-off while sim_time strictly
    // drops once a clamp fires
    let mut off_cfg = cfg_for(Algo::Laq, WireMode::Sync, 0, 1, 1);
    off_cfg.scenario.workers = vec![
        WorkerFaults { worker: 1, straggle_alpha: Some(1.2), ..WorkerFaults::default() },
        WorkerFaults { worker: 2, straggle_alpha: Some(2.5), ..WorkerFaults::default() },
    ];
    let mut on_cfg = off_cfg.clone();
    on_cfg.resilience = ResilienceCfg { quorum: 0.5, ..ResilienceCfg::default() };
    on_cfg.validate().unwrap();

    let off = run_trace(&off_cfg);
    let on = run_trace(&on_cfg);
    let (_, _, clamped) = on.stats;
    assert!(clamped > 0, "two Pareto stragglers never fell behind a 0.5 quorum");
    assert_eq!(on.theta, off.theta, "the quorum clamp must not touch θ");
    assert_eq!(on.bits, off.bits, "the quorum clamp must not touch the bit ledger");
    assert_eq!(on.rounds, off.rounds);
    assert_eq!(on.per_worker_rounds, off.per_worker_rounds);
    assert!(
        on.sim_time < off.sim_time,
        "a fired quorum clamp must strictly shrink sim_time: on={} off={}",
        on.sim_time,
        off.sim_time
    );
}

#[test]
fn checkpoint_v6_resumes_health_state_bit_exactly() {
    // a save placed after the straggler's demotion must carry the whole
    // health machine — EMAs, streaks, phases, demotion rounds — so the
    // resumed run replays the remaining cadence schedule bit-for-bit
    // against the uninterrupted one
    let mut cfg = cfg_for(Algo::Laq, WireMode::Sync, 0, 1, 1);
    cfg.iters = 60;
    cfg.scenario.workers = straggler_fleet();
    cfg.resilience = healing_policy();
    cfg.validate().unwrap();

    let mut reference = laq::algo::build_native(&cfg).unwrap();
    for _ in 0..cfg.iters {
        reference.step().unwrap();
    }

    let dir = std::env::temp_dir().join("laq_resilience_ckpt");
    let path = dir.join("healing.ckpt");
    let mut first = laq::algo::build_native(&cfg).unwrap();
    for _ in 0..30 {
        first.step().unwrap();
    }
    first.save_checkpoint(&path).unwrap();
    let mut resumed = laq::algo::build_native(&cfg).unwrap();
    resumed.load_checkpoint(&path).unwrap();
    for m in 0..cfg.workers {
        assert_eq!(
            resumed.worker_health(m),
            first.worker_health(m),
            "worker {m} health state did not survive the checkpoint"
        );
    }
    for _ in 30..cfg.iters {
        resumed.step().unwrap();
    }

    assert_eq!(
        reference.theta(),
        resumed.theta(),
        "θ diverged across the checkpoint boundary"
    );
    assert_eq!(reference.clocks(), resumed.clocks(), "clocks diverged");
    for m in 0..cfg.workers {
        assert_eq!(
            reference.worker_health(m),
            resumed.worker_health(m),
            "worker {m} health state diverged after resume"
        );
    }

    // a checkpoint carrying health state must refuse a resilience-less
    // trainer — silently dropping the health machine would fork the
    // cadence schedule from the saved run
    let mut bare_cfg = cfg.clone();
    bare_cfg.resilience = ResilienceCfg::default();
    let mut bare = laq::algo::build_native(&bare_cfg).unwrap();
    let err = bare.load_checkpoint(&path).unwrap_err().to_string();
    assert!(
        err.contains("resilience"),
        "wrong error for a health-bearing checkpoint: {err}"
    );
    let _ = std::fs::remove_dir_all(&dir);
}
