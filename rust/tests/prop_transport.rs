//! Adversarial property tests for the TCP transport's frame grammar
//! (`laq::comm::transport`).  The decoder sits on a network socket, so
//! its contract is total over arbitrary bytes:
//!
//!   * every strict byte prefix of a valid frame is an error — never a
//!     panic, never a partial parse;
//!   * an oversized declared length is rejected from the 5-byte header
//!     alone, before any allocation can happen;
//!   * an unknown frame-kind byte is rejected;
//!   * every typed message parser (`Hello`/`Broadcast`/`Report`/`Bye`)
//!     is total over truncated and over-long bodies;
//!   * random garbage never panics the decoder.

use laq::comm::transport::{
    Broadcast, Bye, Frame, FrameKind, Hello, Report, HEADER_BYTES, MAX_FRAME_BYTES,
    PROTO_VERSION,
};
use laq::prop_assert;
use laq::quant::innovation::{InnovationQuantizer, QuantizedInnovation};
use laq::util::prop::Prop;
use laq::util::rng::Rng;

// ---- representative frames ------------------------------------------------

fn sample_hello() -> Frame {
    Hello {
        proto: PROTO_VERSION,
        worker: 3,
        n_workers: 8,
        dim: 7841,
        seed: 0xDEAD_BEEF,
        fingerprint: 0x0123_4567_89AB_CDEF,
    }
    .to_frame()
}

fn sample_broadcast(dim: usize) -> Frame {
    Broadcast {
        round: 41,
        width: 3,
        flags: 0,
        force_upload: false,
        rhs_common: 0.25,
        theta: (0..dim).map(|i| i as f32 * 0.5 - 1.0).collect(),
    }
    .to_frame()
}

fn sample_report(payload: Vec<u8>) -> Frame {
    Report {
        round: 41,
        loss: 0.693,
        lhs: 1.5,
        rhs: 2.5,
        eps_sq: 1e-4,
        uploaded: !payload.is_empty(),
        payload,
    }
    .to_frame()
}

fn sample_bye() -> Frame {
    Bye { report_tx_bytes: 123_456, bcast_rx_bytes: 654_321 }.to_frame()
}

fn sample_frames() -> Vec<Frame> {
    vec![
        sample_hello(),
        Frame::new(FrameKind::HelloAck, Vec::new()),
        sample_broadcast(17),
        sample_report(vec![0xAB; 37]),
        sample_report(Vec::new()),
        Frame::new(FrameKind::Eval, Vec::new()),
        Frame::new(FrameKind::EvalReply, vec![0; 8]),
        Frame::new(FrameKind::Shutdown, Vec::new()),
        sample_bye(),
    ]
}

// ---- frame-level grammar --------------------------------------------------

#[test]
fn every_strict_prefix_of_every_frame_errors() {
    for f in sample_frames() {
        let enc = f.encode();
        assert_eq!(enc.len(), f.wire_len());
        for cut in 0..enc.len() {
            let r = Frame::decode(&enc[..cut]);
            assert!(
                r.is_err(),
                "strict prefix {cut}/{} of {:?} frame decoded",
                enc.len(),
                f.kind
            );
        }
        // the full buffer round-trips and consumes exactly itself
        let (back, used) = Frame::decode(&enc).expect("full frame decodes");
        assert_eq!(used, enc.len());
        assert_eq!(back, f);
        // trailing bytes belong to the next frame, not this one
        let mut stream = enc.clone();
        stream.extend_from_slice(&[0x55; 9]);
        let (back2, used2) = Frame::decode(&stream).expect("frame + tail decodes");
        assert_eq!(used2, enc.len());
        assert_eq!(back2, f);
    }
}

#[test]
fn oversized_declared_length_is_rejected_from_the_header() {
    // A hostile peer declares a huge body.  The cap check must fire from
    // the 5 header bytes alone — before `Vec::with_capacity` — so the
    // decoder can never be driven into an unbounded allocation.
    for len in [MAX_FRAME_BYTES as u32 + 1, u32::MAX / 2, u32::MAX] {
        let mut h = vec![FrameKind::Report as u8];
        h.extend_from_slice(&len.to_le_bytes());
        assert!(Frame::decode(&h).is_err(), "declared len {len} accepted");
        // ...and a longer buffer with the same header fails identically,
        // proving it is the cap (not truncation) doing the rejecting
        let mut padded = h.clone();
        padded.extend_from_slice(&[0; 64]);
        assert!(Frame::decode(&padded).is_err());
    }
    // the cap itself is legal: a zero-length body at any valid kind is a
    // well-formed frame
    let empty = Frame::new(FrameKind::Shutdown, Vec::new());
    assert!(Frame::decode(&empty.encode()).is_ok());
}

#[test]
fn unknown_kind_bytes_are_rejected() {
    for c in 0u8..=255 {
        let mut buf = vec![c];
        buf.extend_from_slice(&0u32.to_le_bytes());
        let r = Frame::decode(&buf);
        match FrameKind::from_code(c) {
            Some(kind) => {
                let (f, used) = r.expect("valid kind with empty body decodes");
                assert_eq!((f.kind, used), (kind, HEADER_BYTES));
                assert!(f.body.is_empty());
            }
            None => assert!(r.is_err(), "kind byte 0x{c:02x} accepted"),
        }
    }
}

#[test]
fn random_garbage_never_panics_the_decoder() {
    Prop::new().check("Frame::decode is total", |rng| {
        let n = rng.below(256) as usize;
        let buf: Vec<u8> = (0..n).map(|_| rng.below(256) as u8).collect();
        match Frame::decode(&buf) {
            Ok((f, used)) => {
                prop_assert!(used <= buf.len(), "consumed past the buffer");
                prop_assert!(used == HEADER_BYTES + f.body.len(), "consumed != frame size");
            }
            Err(_) => {}
        }
        Ok(())
    });
}

// ---- typed-body grammar ---------------------------------------------------

/// Re-frame `body[..cut]` under `kind` — a valid frame whose body was
/// truncated in flight (the length prefix is self-consistent, so this
/// exercises the typed parsers, not the frame decoder).
fn truncated(kind: FrameKind, body: &[u8], cut: usize) -> Frame {
    Frame::new(kind, body[..cut].to_vec())
}

#[test]
fn truncated_hello_bodies_error() {
    let f = sample_hello();
    for cut in 0..f.body.len() {
        assert!(Hello::from_frame(&truncated(FrameKind::Hello, &f.body, cut)).is_err());
    }
    assert!(Hello::from_frame(&f).is_ok());
    // over-long bodies are a protocol violation, not silently ignored
    let mut long = f.clone();
    long.body.push(0);
    assert!(Hello::from_frame(&long).is_err());
    // so is the wrong frame kind
    assert!(Hello::from_frame(&sample_bye()).is_err());
}

#[test]
fn truncated_broadcast_bodies_error() {
    let dim = 17;
    let f = sample_broadcast(dim);
    let mut out = Broadcast {
        round: 0,
        width: 0,
        flags: 0,
        force_upload: false,
        rhs_common: 0.0,
        theta: Vec::new(),
    };
    for cut in 0..f.body.len() {
        let t = truncated(FrameKind::Broadcast, &f.body, cut);
        assert!(Broadcast::read_into(&t, dim, &mut out).is_err(), "cut {cut} parsed");
    }
    assert!(Broadcast::read_into(&f, dim, &mut out).is_ok());
    assert_eq!(out.theta.len(), dim);
    let mut long = f.clone();
    long.body.push(0);
    assert!(Broadcast::read_into(&long, dim, &mut out).is_err());
    // a θ sized for a different model dimension must not parse either
    assert!(Broadcast::read_into(&f, dim + 1, &mut out).is_err());
}

#[test]
fn truncated_report_and_bye_bodies_error() {
    // Report: the fixed head (round + 4 metrics + uploaded flag) must be
    // complete; everything after it is payload, whose own truncation is
    // the payload codec's job (see the framed-innovation test below).
    let head_len = 8 + 4 * 8 + 1;
    let f = sample_report(vec![0xCD; 21]);
    for cut in 0..head_len {
        assert!(Report::from_frame(&truncated(FrameKind::Report, &f.body, cut)).is_err());
    }
    let r = Report::from_frame(&f).expect("full report parses");
    assert!(r.uploaded && r.payload.len() == 21);
    // a skip report carries no payload — trailing bytes are a violation
    let skip = sample_report(Vec::new());
    assert!(Report::from_frame(&skip).is_ok());
    let mut long = skip.clone();
    long.body.push(0);
    assert!(Report::from_frame(&long).is_err());

    let b = sample_bye();
    for cut in 0..b.body.len() {
        assert!(Bye::from_frame(&truncated(FrameKind::Bye, &b.body, cut)).is_err());
    }
    assert!(Bye::from_frame(&b).is_ok());
    let mut long = b.clone();
    long.body.push(0);
    assert!(Bye::from_frame(&long).is_err());
}

#[test]
fn truncated_framed_innovation_payloads_error() {
    // The payload inside an uploaded Report rides the framed innovation
    // layout; a payload cut anywhere must surface as Err(Codec) from the
    // codec, never a panic and never a silent short vector.
    Prop::new().check("framed innovation decode is total", |rng| {
        let p = 1 + rng.below(64) as usize;
        let bits = 1 + rng.below(8) as u32;
        let g: Vec<f32> = (0..p).map(|_| rng.normal() as f32).collect();
        let qp = vec![0.0f32; p];
        let (qi, _) = InnovationQuantizer::new(bits).quantize(&g, &qp);
        let enc = qi.encode_framed();
        for cut in 0..enc.len() {
            prop_assert!(
                QuantizedInnovation::decode_framed(&enc[..cut], p).is_err(),
                "prefix {cut}/{} of framed innovation (p={p} b={bits}) decoded",
                enc.len()
            );
        }
        let back = QuantizedInnovation::decode_framed(&enc, p).map_err(|e| e.to_string())?;
        prop_assert!(back == qi, "framed roundtrip mismatch");
        Ok(())
    });
}
