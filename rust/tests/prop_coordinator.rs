//! Property tests on the coordinator invariants (DESIGN.md §6): mirror
//! consistency, aggregate identity, clock bound (7b), and exact bit
//! accounting — under randomized algorithms, sizes and seeds.

use laq::config::{Algo, ModelKind, RunCfg};
use laq::prop_assert;
use laq::util::prop::Prop;
use laq::util::rng::Rng;

fn rand_cfg(rng: &mut Rng) -> RunCfg {
    let algo = Algo::all()[rng.below(9) as usize];
    let mut c = RunCfg::paper_logreg(algo);
    c.data.name = ["ijcnn1", "covtype"][rng.below(2) as usize].into();
    c.data.n_train = 120 + rng.below(200) as usize;
    c.data.n_test = 40;
    c.data.seed = rng.next_u64();
    c.workers = 2 + rng.below(5) as usize;
    c.bits = 1 + rng.below(8) as u32;
    c.iters = 5 + rng.below(20) as usize;
    c.batch = c.workers * (1 + rng.below(8) as usize);
    c.seed = rng.next_u64();
    c.criterion.d = 1 + rng.below(10) as usize;
    c.criterion.xi = vec![0.8 / c.criterion.d as f64; c.criterion.d];
    c.criterion.t_max = c.criterion.d + rng.below(20) as usize;
    if rng.bernoulli(0.3) {
        c.data.hetero_alpha = Some(0.2 + rng.uniform());
    }
    c
}

#[test]
fn mirror_consistency_under_all_algorithms() {
    Prop::with_cases(40).check("server mirror == worker mirror", |rng| {
        let cfg = rand_cfg(rng);
        let mut t = laq::algo::build_native(&cfg).map_err(|e| e.to_string())?;
        for _ in 0..cfg.iters {
            t.step().map_err(|e| e.to_string())?;
            for m in 0..t.n_workers() {
                prop_assert!(
                    t.worker_mirror(m) == t.server_mirror(m),
                    "mirror drift on {} worker {m}",
                    cfg.algo.name()
                );
            }
        }
        Ok(())
    });
}

#[test]
fn aggregate_equals_sum_of_mirrors_for_lazy_algos() {
    Prop::with_cases(30).check("agg == sum(mirrors)", |rng| {
        let mut cfg = rand_cfg(rng);
        cfg.algo = [Algo::Gd, Algo::Qgd, Algo::Lag, Algo::Laq, Algo::Slaq]
            [rng.below(5) as usize];
        let mut t = laq::algo::build_native(&cfg).map_err(|e| e.to_string())?;
        for _ in 0..cfg.iters {
            t.step().map_err(|e| e.to_string())?;
            let drift = t.aggregate_drift();
            prop_assert!(
                drift < 1e-3,
                "aggregate drift {drift} on {}",
                cfg.algo.name()
            );
        }
        Ok(())
    });
}

#[test]
fn clock_bound_7b_holds() {
    Prop::with_cases(25).check("t_m <= t_max always", |rng| {
        let mut cfg = rand_cfg(rng);
        cfg.algo = [Algo::Lag, Algo::Laq, Algo::Slaq][rng.below(3) as usize];
        cfg.iters = cfg.criterion.t_max * 2 + 10;
        let mut t = laq::algo::build_native(&cfg).map_err(|e| e.to_string())?;
        for _ in 0..cfg.iters {
            t.step().map_err(|e| e.to_string())?;
            for (m, &c) in t.clocks().iter().enumerate() {
                prop_assert!(
                    c <= cfg.criterion.t_max,
                    "worker {m} clock {c} > t_max {}",
                    cfg.criterion.t_max
                );
            }
        }
        Ok(())
    });
}

#[test]
fn bit_accounting_is_exact() {
    Prop::with_cases(30).check("bits == Σ per-upload wire size", |rng| {
        let cfg = rand_cfg(rng);
        let p = match cfg.data.name.as_str() {
            "ijcnn1" => 22 * 2,
            _ => 54 * 7,
        };
        let mut t = laq::algo::build_native(&cfg).map_err(|e| e.to_string())?;
        let mut expected_bits = 0u64;
        for _ in 0..cfg.iters {
            let s = t.step().map_err(|e| e.to_string())?;
            // per-upload cost by codec (SSGD is message-dependent: check
            // via its own counter instead)
            let per_upload: Option<u64> = match cfg.algo {
                Algo::Gd | Algo::Lag | Algo::Sgd => Some(32 * p as u64),
                Algo::Qgd | Algo::Laq | Algo::Slaq => {
                    Some(32 + cfg.bits as u64 * p as u64)
                }
                Algo::Qsgd => Some(32 + (cfg.bits as u64 + 1) * p as u64),
                Algo::EfSgd => Some(32 + p as u64),
                Algo::Ssgd => None,
            };
            if let Some(c) = per_upload {
                prop_assert!(
                    s.bits == c * s.uploads as u64,
                    "iter bits {} != {c} × {} uploads ({})",
                    s.bits,
                    s.uploads,
                    cfg.algo.name()
                );
            }
            expected_bits += s.bits;
        }
        prop_assert!(
            t.net.uplink_bits() == expected_bits,
            "cumulative bits mismatch"
        );
        Ok(())
    });
}

#[test]
fn per_worker_rounds_sum_to_total() {
    Prop::with_cases(25).check("Σ_m rounds_m == total rounds", |rng| {
        let cfg = rand_cfg(rng);
        let mut t = laq::algo::build_native(&cfg).map_err(|e| e.to_string())?;
        for _ in 0..cfg.iters {
            t.step().map_err(|e| e.to_string())?;
        }
        let total: u64 = t.net.per_worker_rounds().iter().sum();
        prop_assert!(total == t.net.uplink_rounds(), "round accounting");
        Ok(())
    });
}

#[test]
fn deterministic_replay() {
    Prop::with_cases(15).check("same seed -> identical trajectory", |rng| {
        let cfg = rand_cfg(rng);
        let run = |cfg: &RunCfg| -> Result<(Vec<f32>, u64, u64), String> {
            let mut t = laq::algo::build_native(cfg).map_err(|e| e.to_string())?;
            for _ in 0..cfg.iters {
                t.step().map_err(|e| e.to_string())?;
            }
            Ok((
                t.theta().to_vec(),
                t.net.uplink_rounds(),
                t.net.uplink_bits(),
            ))
        };
        let a = run(&cfg)?;
        let b = run(&cfg)?;
        prop_assert!(a == b, "nondeterministic run for {}", cfg.algo.name());
        Ok(())
    });
}

#[test]
fn loss_decreases_for_deterministic_algorithms() {
    Prop::with_cases(15).check("loss trend down", |rng| {
        let mut cfg = rand_cfg(rng);
        cfg.algo = [Algo::Gd, Algo::Qgd, Algo::Lag, Algo::Laq][rng.below(4) as usize];
        cfg.iters = 40;
        cfg.model = ModelKind::LogReg;
        // covtype-like has feature scales up to 10× -> L is large and the
        // paper stepsize 0.02 can diverge (true for GD too); descent is
        // only guaranteed for α < 2/L, so pin the well-conditioned dataset
        cfg.data.name = "ijcnn1".into();
        cfg.alpha = 0.02;
        let mut t = laq::algo::build_native(&cfg).map_err(|e| e.to_string())?;
        let first = t.step().map_err(|e| e.to_string())?.loss;
        let mut last = first;
        for _ in 1..cfg.iters {
            last = t.step().map_err(|e| e.to_string())?.loss;
        }
        prop_assert!(
            last < first,
            "{}: loss {first} -> {last} did not decrease",
            cfg.algo.name()
        );
        Ok(())
    });
}
