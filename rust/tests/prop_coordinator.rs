//! Property tests on the coordinator invariants (DESIGN.md §6): mirror
//! consistency, aggregate identity, clock bound (7b), exact bit
//! accounting, and the self-healing policy layer (cadence demotion,
//! retry caps, backoff billing, grid purity) — under randomized
//! algorithms, sizes, fault fleets and seeds.

use laq::config::{Algo, ModelKind, ResilienceCfg, RunCfg, WireMode, WorkerFaults};
use laq::prop_assert;
use laq::util::prop::Prop;
use laq::util::rng::Rng;

fn rand_cfg(rng: &mut Rng) -> RunCfg {
    let algo = Algo::all()[rng.below(9) as usize];
    let mut c = RunCfg::paper_logreg(algo);
    c.data.name = ["ijcnn1", "covtype"][rng.below(2) as usize].into();
    c.data.n_train = 120 + rng.below(200) as usize;
    c.data.n_test = 40;
    c.data.seed = rng.next_u64();
    c.workers = 2 + rng.below(5) as usize;
    c.bits = 1 + rng.below(8) as u32;
    c.iters = 5 + rng.below(20) as usize;
    c.batch = c.workers * (1 + rng.below(8) as usize);
    c.seed = rng.next_u64();
    c.criterion.d = 1 + rng.below(10) as usize;
    c.criterion.xi = vec![0.8 / c.criterion.d as f64; c.criterion.d];
    c.criterion.t_max = c.criterion.d + rng.below(20) as usize;
    if rng.bernoulli(0.3) {
        c.data.hetero_alpha = Some(0.2 + rng.uniform());
    }
    c
}

#[test]
fn mirror_consistency_under_all_algorithms() {
    Prop::with_cases(40).check("server mirror == worker mirror", |rng| {
        let cfg = rand_cfg(rng);
        let mut t = laq::algo::build_native(&cfg).map_err(|e| e.to_string())?;
        for _ in 0..cfg.iters {
            t.step().map_err(|e| e.to_string())?;
            for m in 0..t.n_workers() {
                // under async-cross an in-flight upload makes the server
                // mirror legitimately lag the worker's until its landing
                // round; the lock-step contract applies whenever nothing
                // is in flight (always, under the other wire modes)
                if t.worker_in_flight(m) {
                    continue;
                }
                prop_assert!(
                    t.worker_mirror(m) == t.server_mirror(m),
                    "mirror drift on {} worker {m}",
                    cfg.algo.name()
                );
            }
        }
        Ok(())
    });
}

#[test]
fn landing_schedule_is_a_bounded_reorder_permutation() {
    // the async wire phase's intra-round landing order: for any
    // (seed, M, bound), a valid permutation with |π(m) − m| ≤ bound
    Prop::with_cases(150).check("landing order bounded permutation", |rng| {
        let n = 1 + rng.below(40) as usize;
        let bound = rng.below(n as u64 + 3) as usize;
        let keys: Vec<u64> = (0..n).map(|_| rng.next_u64()).collect();
        let (mut win, mut out) = (Vec::new(), Vec::new());
        laq::algo::landing_order(&keys, bound, &mut win, &mut out);
        let mut sorted = out.clone();
        sorted.sort_unstable();
        prop_assert!(
            sorted == (0..n).collect::<Vec<_>>(),
            "not a permutation of 0..{n} (bound {bound}): {out:?}"
        );
        for (pos, &m) in out.iter().enumerate() {
            let d = pos.abs_diff(m);
            prop_assert!(
                d <= bound,
                "worker {m} displaced {d} > bound {bound} (pos {pos})"
            );
        }
        Ok(())
    });
}

#[test]
fn cross_round_lag_rule_is_bounded_fifo_and_pure() {
    // the cross-round landing rule: per-(seed, worker, round) lags stay
    // within the bound, deadlines are monotone per worker (FIFO channel)
    // and never stray outside [round, round + bound], and the whole
    // schedule is a pure function of its inputs
    Prop::with_cases(150).check("cross-round lag rule", |rng| {
        let lat = laq::comm::LatencyModel::default();
        let seed = rng.next_u64();
        let m_all = 1 + rng.below(8) as usize;
        let bound = rng.below(6) as usize;
        let rounds = 5 + rng.below(60) as usize;
        let mut prev = vec![0usize; m_all];
        for k in 0..rounds {
            for (m, prev_m) in prev.iter_mut().enumerate() {
                let lag = lat.round_lag(seed, m as u64, k as u64, bound);
                prop_assert!(lag <= bound, "lag {lag} > bound {bound}");
                prop_assert!(
                    lag == lat.round_lag(seed, m as u64, k as u64, bound),
                    "round_lag is not a pure function"
                );
                let d = laq::algo::cross_deadline(*prev_m, k, lag);
                prop_assert!(d >= k, "deadline {d} before round {k}");
                prop_assert!(d <= k + bound, "deadline {d} > {k} + {bound}");
                prop_assert!(d >= *prev_m, "FIFO violated: {d} < {}", *prev_m);
                *prev_m = d;
            }
        }
        Ok(())
    });
}

#[test]
fn aggregate_equals_sum_of_mirrors_for_lazy_algos() {
    Prop::with_cases(30).check("agg == sum(mirrors)", |rng| {
        let mut cfg = rand_cfg(rng);
        cfg.algo = [Algo::Gd, Algo::Qgd, Algo::Lag, Algo::Laq, Algo::Slaq]
            [rng.below(5) as usize];
        let mut t = laq::algo::build_native(&cfg).map_err(|e| e.to_string())?;
        for _ in 0..cfg.iters {
            t.step().map_err(|e| e.to_string())?;
            let drift = t.aggregate_drift();
            prop_assert!(
                drift < 1e-3,
                "aggregate drift {drift} on {}",
                cfg.algo.name()
            );
        }
        Ok(())
    });
}

#[test]
fn clock_bound_7b_holds() {
    Prop::with_cases(25).check("t_m <= t_max always", |rng| {
        let mut cfg = rand_cfg(rng);
        cfg.algo = [Algo::Lag, Algo::Laq, Algo::Slaq][rng.below(3) as usize];
        cfg.iters = cfg.criterion.t_max * 2 + 10;
        let mut t = laq::algo::build_native(&cfg).map_err(|e| e.to_string())?;
        for _ in 0..cfg.iters {
            t.step().map_err(|e| e.to_string())?;
            for (m, &c) in t.clocks().iter().enumerate() {
                prop_assert!(
                    c <= cfg.criterion.t_max,
                    "worker {m} clock {c} > t_max {}",
                    cfg.criterion.t_max
                );
            }
        }
        Ok(())
    });
}

#[test]
fn bit_accounting_is_exact() {
    Prop::with_cases(30).check("bits == Σ per-upload wire size", |rng| {
        let cfg = rand_cfg(rng);
        let p = match cfg.data.name.as_str() {
            "ijcnn1" => 22 * 2,
            _ => 54 * 7,
        };
        let mut t = laq::algo::build_native(&cfg).map_err(|e| e.to_string())?;
        let mut expected_bits = 0u64;
        for _ in 0..cfg.iters {
            let s = t.step().map_err(|e| e.to_string())?;
            // per-upload cost by codec (SSGD is message-dependent: check
            // via its own counter instead)
            let per_upload: Option<u64> = match cfg.algo {
                Algo::Gd | Algo::Lag | Algo::Sgd => Some(32 * p as u64),
                Algo::Qgd | Algo::Laq | Algo::Slaq => {
                    Some(32 + cfg.bits as u64 * p as u64)
                }
                Algo::Qsgd => Some(32 + (cfg.bits as u64 + 1) * p as u64),
                Algo::EfSgd => Some(32 + p as u64),
                Algo::Ssgd => None,
            };
            if let Some(c) = per_upload {
                prop_assert!(
                    s.bits == c * s.uploads as u64,
                    "iter bits {} != {c} × {} uploads ({})",
                    s.bits,
                    s.uploads,
                    cfg.algo.name()
                );
            }
            expected_bits += s.bits;
        }
        prop_assert!(
            t.net.uplink_bits() == expected_bits,
            "cumulative bits mismatch"
        );
        Ok(())
    });
}

#[test]
fn per_worker_rounds_sum_to_total() {
    Prop::with_cases(25).check("Σ_m rounds_m == total rounds", |rng| {
        let cfg = rand_cfg(rng);
        let mut t = laq::algo::build_native(&cfg).map_err(|e| e.to_string())?;
        for _ in 0..cfg.iters {
            t.step().map_err(|e| e.to_string())?;
        }
        let total: u64 = t.net.per_worker_rounds().iter().sum();
        prop_assert!(total == t.net.uplink_rounds(), "round accounting");
        Ok(())
    });
}

#[test]
fn deterministic_replay() {
    Prop::with_cases(15).check("same seed -> identical trajectory", |rng| {
        let cfg = rand_cfg(rng);
        let run = |cfg: &RunCfg| -> Result<(Vec<f32>, u64, u64), String> {
            let mut t = laq::algo::build_native(cfg).map_err(|e| e.to_string())?;
            for _ in 0..cfg.iters {
                t.step().map_err(|e| e.to_string())?;
            }
            Ok((
                t.theta().to_vec(),
                t.net.uplink_rounds(),
                t.net.uplink_bits(),
            ))
        };
        let a = run(&cfg)?;
        let b = run(&cfg)?;
        prop_assert!(a == b, "nondeterministic run for {}", cfg.algo.name());
        Ok(())
    });
}

fn rand_resilience(rng: &mut Rng) -> ResilienceCfg {
    let base = 1e-4 + rng.uniform() * 1e-3;
    ResilienceCfg {
        cadence: 2 + rng.below(4) as usize,
        miss_threshold: 1 + rng.below(3) as u32,
        restore_rounds: 1 + rng.below(6) as u32,
        max_retries: rng.below(4) as u32,
        backoff_base: base,
        backoff_cap: base * (1.0 + rng.uniform() * 7.0),
        quorum: if rng.bernoulli(0.5) { 0.3 + rng.uniform() * 0.7 } else { 0.0 },
        staleness_slack: 0,
    }
}

/// A lazy-algorithm config with a random fault fleet and a random
/// resilience policy — the input space of the self-healing contracts.
fn rand_resilient_cfg(rng: &mut Rng) -> RunCfg {
    let mut c = rand_cfg(rng);
    c.algo = [Algo::Lag, Algo::Laq, Algo::Slaq][rng.below(3) as usize];
    c.resilience = rand_resilience(rng);
    let mut fleet = Vec::new();
    for m in 0..c.workers {
        if !rng.bernoulli(0.6) {
            continue;
        }
        let straggles = rng.bernoulli(0.7);
        fleet.push(WorkerFaults {
            worker: m,
            straggle_alpha: straggles.then(|| 1.05 + rng.uniform() * 1.5),
            deadline: if straggles && rng.bernoulli(0.7) {
                1.3 + rng.uniform() * 3.0
            } else {
                f64::INFINITY
            },
            corrupt_rate: if rng.bernoulli(0.4) { 0.2 + rng.uniform() * 0.4 } else { 0.0 },
            ..WorkerFaults::default()
        });
    }
    c.scenario.workers = fleet;
    c
}

#[test]
fn cadence_demotion_is_monotone_in_miss_streak() {
    use laq::algo::resilience::{observe_round, HealthPhase, WorkerHealth};
    // the health machine's demotion rule: for a fixed policy, a worker
    // with a longer accumulated miss streak never demotes later than one
    // with a shorter streak — and the demotion lands exactly when the
    // streak reaches miss_threshold
    Prop::with_cases(200).check("demotion monotone in miss streak", |rng| {
        let rcfg = rand_resilience(rng);
        let lo = rng.below(rcfg.miss_threshold as u64 + 2) as u32;
        let hi = lo + rng.below(4) as u32;
        let mk = |streak: u32| WorkerHealth {
            miss_streak: streak,
            phase: if streak == 0 { HealthPhase::Healthy } else { HealthPhase::Probation },
            ..WorkerHealth::default()
        };
        let rounds_to_demote = |mut h: WorkerHealth| -> u32 {
            for r in 1..=64u32 {
                if observe_round(&mut h, &rcfg, r as usize, 1.0, true, false) {
                    return r;
                }
            }
            65
        };
        let fast = rounds_to_demote(mk(hi));
        let slow = rounds_to_demote(mk(lo));
        prop_assert!(
            fast <= slow,
            "streak {hi} demoted after {fast} misses, streak {lo} after {slow}"
        );
        let expect = rcfg.miss_threshold.saturating_sub(lo).max(1);
        prop_assert!(
            slow == expect,
            "streak {lo}, threshold {}: demoted after {slow} misses, expected {expect}",
            rcfg.miss_threshold
        );
        Ok(())
    });
}

#[test]
fn backoff_delay_is_exact_to_the_formula() {
    use laq::algo::resilience::backoff_delay;
    // min(backoff_base · 2^(r−1), backoff_cap), bit-exactly — scaling by
    // a power of two is lossless, so the contract is == not ≈
    Prop::with_cases(300).check("backoff == min(base·2^(r−1), cap)", |rng| {
        let rcfg = rand_resilience(rng);
        let r = 1 + rng.below(8) as u32;
        let got = backoff_delay(&rcfg, r);
        let expect =
            (rcfg.backoff_base * f64::powi(2.0, (r - 1) as i32)).min(rcfg.backoff_cap);
        prop_assert!(
            got == expect,
            "attempt {r}, base {}, cap {}: got {got}, expected {expect}",
            rcfg.backoff_base,
            rcfg.backoff_cap
        );
        prop_assert!(
            got <= rcfg.backoff_cap && got >= 0.0,
            "backoff {got} escaped [0, cap = {}]",
            rcfg.backoff_cap
        );
        Ok(())
    });
}

#[test]
fn retry_ladder_respects_the_cap_and_bills_backoff_exactly() {
    use laq::algo::resilience::backoff_delay;
    // live trainer, random fault fleet: no round plan ever uses more
    // than max_retries attempts, every superseded corrupt frame maps to
    // an attempt, and the billed backoff is exactly the formula summed
    // over the attempts actually used
    Prop::with_cases(15).check("retries <= max, backoff billed exactly", |rng| {
        let mut cfg = rand_resilient_cfg(rng);
        cfg.resilience.max_retries = 1 + rng.below(3) as u32;
        cfg.validate().map_err(|e| e.to_string())?;
        let mut t = laq::algo::build_native(&cfg).map_err(|e| e.to_string())?;
        for _ in 0..cfg.iters {
            t.step().map_err(|e| e.to_string())?;
            for (m, plan) in t.round_plans().iter().enumerate() {
                prop_assert!(
                    plan.retries_used <= cfg.resilience.max_retries,
                    "worker {m} used {} retries > max {}",
                    plan.retries_used,
                    cfg.resilience.max_retries
                );
                prop_assert!(
                    plan.extra_rejected_frames <= plan.retries_used,
                    "worker {m}: {} superseded frames from {} attempts",
                    plan.extra_rejected_frames,
                    plan.retries_used
                );
                let mut expect = 0.0;
                for r in 1..=plan.retries_used {
                    expect += backoff_delay(&cfg.resilience, r);
                }
                prop_assert!(
                    plan.backoff_time == expect,
                    "worker {m}: billed backoff {} drifted from the formula {expect}",
                    plan.backoff_time
                );
            }
        }
        Ok(())
    });
}

#[test]
fn resilience_policy_is_pure_across_the_thread_shard_grid() {
    // the whole policy layer — cadence verdicts, retry ladders, quorum
    // clamps, health folds — is a pure function of (seed, config):
    // reruns and every {1,4}×{1,7} grid point agree bit-for-bit, under
    // sync and async wire phases
    Prop::with_cases(8).check("resilience (seed, config)-pure", |rng| {
        let mut cfg = rand_resilient_cfg(rng);
        if rng.bernoulli(0.4) {
            cfg.wire_mode = WireMode::Async;
            cfg.staleness_bound = 1 + rng.below(3) as usize;
        }
        cfg.validate().map_err(|e| e.to_string())?;
        let run = |cfg: &RunCfg| -> Result<_, String> {
            let mut t = laq::algo::build_native(cfg).map_err(|e| e.to_string())?;
            for _ in 0..cfg.iters {
                t.step().map_err(|e| e.to_string())?;
            }
            let health: Vec<_> = (0..cfg.workers).map(|m| *t.worker_health(m)).collect();
            Ok((
                t.theta().to_vec(),
                t.net.uplink_bits(),
                t.net.sim_time().to_bits(),
                t.resilience_stats(),
                health,
            ))
        };
        let base = run(&cfg)?;
        let again = run(&cfg)?;
        prop_assert!(base == again, "resilient rerun diverged ({})", cfg.algo.name());
        for (threads, shards) in [(1usize, 7usize), (4, 1), (4, 7)] {
            let mut c = cfg.clone();
            c.threads = threads;
            c.server_shards = shards;
            let t = run(&c)?;
            prop_assert!(
                base == t,
                "resilience threads={threads} shards={shards} diverged ({})",
                cfg.algo.name()
            );
        }
        Ok(())
    });
}

#[test]
fn loss_decreases_for_deterministic_algorithms() {
    Prop::with_cases(15).check("loss trend down", |rng| {
        let mut cfg = rand_cfg(rng);
        cfg.algo = [Algo::Gd, Algo::Qgd, Algo::Lag, Algo::Laq][rng.below(4) as usize];
        cfg.iters = 40;
        cfg.model = ModelKind::LogReg;
        // covtype-like has feature scales up to 10× -> L is large and the
        // paper stepsize 0.02 can diverge (true for GD too); descent is
        // only guaranteed for α < 2/L, so pin the well-conditioned dataset
        cfg.data.name = "ijcnn1".into();
        cfg.alpha = 0.02;
        let mut t = laq::algo::build_native(&cfg).map_err(|e| e.to_string())?;
        let first = t.step().map_err(|e| e.to_string())?.loss;
        let mut last = first;
        for _ in 1..cfg.iters {
            last = t.step().map_err(|e| e.to_string())?.loss;
        }
        prop_assert!(
            last < first,
            "{}: loss {first} -> {last} did not decrease",
            cfg.algo.name()
        );
        Ok(())
    });
}
