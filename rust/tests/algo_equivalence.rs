//! Backend-equivalence tests: the PJRT trainer (AOT artifacts) and the
//! native trainer must produce matching optimization trajectories —
//! parameters are interchangeable between backends by construction
//! (identical flat layouts and loss normalization).
//!
//! Skipped with a notice when artifacts are missing or the shapes don't
//! match the artifact set.

use laq::algo::{build_native, build_pjrt};
use laq::config::{Algo, RunCfg};
use laq::runtime::Runtime;
use std::sync::Arc;

fn runtime() -> Option<Arc<Runtime>> {
    match Runtime::open("artifacts") {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("SKIP backend-equivalence tests: {e}");
            None
        }
    }
}

fn artifact_cfg(algo: Algo) -> RunCfg {
    let mut cfg = RunCfg::paper_logreg(algo);
    // must match python/compile/aot.py constants
    cfg.data.n_train = 10_000;
    cfg.data.n_test = 2_000;
    cfg.workers = 10;
    cfg.iters = 3;
    cfg
}

#[test]
fn laq_trajectory_matches_across_backends() {
    let Some(rt) = runtime() else { return };
    let cfg = artifact_cfg(Algo::Laq);
    let mut nat = build_native(&cfg).unwrap();
    let mut pj = build_pjrt(&cfg, rt).unwrap();
    for k in 0..cfg.iters {
        let sn = nat.step().unwrap();
        let sp = pj.step().unwrap();
        assert!(
            (sn.loss - sp.loss).abs() < 1e-4 * sn.loss.abs().max(1.0),
            "iter {k}: loss {} vs {}",
            sn.loss,
            sp.loss
        );
        // identical communication decisions — the criterion must agree
        assert_eq!(sn.uploads, sp.uploads, "iter {k} upload counts");
        assert_eq!(sn.bits, sp.bits, "iter {k} bits");
    }
    // parameters stay close after 3 steps
    let (tn, tp) = (nat.theta(), pj.theta());
    let mut worst = 0.0f32;
    for (a, b) in tn.iter().zip(tp) {
        worst = worst.max((a - b).abs());
    }
    assert!(worst < 1e-4, "theta divergence {worst}");
}

#[test]
fn gd_loss_matches_across_backends() {
    let Some(rt) = runtime() else { return };
    let cfg = artifact_cfg(Algo::Gd);
    let mut nat = build_native(&cfg).unwrap();
    let mut pj = build_pjrt(&cfg, rt).unwrap();
    for _ in 0..2 {
        let sn = nat.step().unwrap();
        let sp = pj.step().unwrap();
        assert!((sn.loss - sp.loss).abs() < 1e-4 * sn.loss.abs().max(1.0));
    }
}

#[test]
fn stochastic_batch_path_matches_across_backends() {
    let Some(rt) = runtime() else { return };
    let cfg = artifact_cfg(Algo::Slaq);
    let mut nat = build_native(&cfg).unwrap();
    let mut pj = build_pjrt(&cfg, rt).unwrap();
    // identical seeds -> identical batch index draws -> comparable losses
    for k in 0..2 {
        let sn = nat.step().unwrap();
        let sp = pj.step().unwrap();
        assert!(
            (sn.loss - sp.loss).abs() < 1e-3 * sn.loss.abs().max(1.0),
            "iter {k}: {} vs {}",
            sn.loss,
            sp.loss
        );
    }
}
