//! Contracts of the quantized θ broadcast (`cfg.downlink`):
//!
//! * **exact regression** — `downlink = exact` is the pre-existing
//!   broadcast: the worker view IS the server θ every round and each
//!   round bills exactly `32 · dim` downlink bits (the golden
//!   fingerprints in `rust/tests/wire_equivalence.rs` additionally pin
//!   the full traces bit-for-bit).
//! * **the headline win** — on strongly convex logreg, `downlink =
//!   quantized` ends within 5% of the exact-downlink final loss while
//!   moving strictly fewer TOTAL (uplink + downlink) bits at the same
//!   iteration count (the acceptance criterion; the `trainer_bits`
//!   bench group records the same comparison in `BENCH_trainer.json`).
//! * **per-seed purity** — the quantized downlink trace (losses, bits
//!   in both directions, per-shard widths, worker θ view) is a pure
//!   function of (seed, config): identical across reruns and across
//!   every (threads, shards) combination — the shard partition is the
//!   fixed `DELTA_BLOCK` grid, never the wall-clock `server_shards`.
//! * **accounting exactness** — after the one exact priming round,
//!   every round's downlink charge is exactly the sum of the per-shard
//!   framed sections `Σ_s (32 + 8 + w_s · p_s)`, billed as ONE
//!   broadcast message, and `total_bits = uplink_bits + downlink_bits`.
//! * **mirror tracking** — the worker view reconstructed from the wire
//!   tracks the server θ within the quantization grid, round over round.
//! * **v5 checkpoint resume** — the downlink mirror + per-shard fold
//!   state persist, and a mid-run resume replays the remaining
//!   quantized stream bit-for-bit even on a trainer configured exact.

use laq::config::{Algo, DownlinkMode, RunCfg, WireMode};
use laq::coordinator::server::DELTA_BLOCK;

fn cfg_for(downlink: DownlinkMode, threads: usize, shards: usize) -> RunCfg {
    let mut c = RunCfg::paper_logreg(Algo::Laq);
    // mnist-like keeps p = 7840 ⇒ 8 fixed downlink shards (7 full
    // DELTA_BLOCKs + one 672-coordinate tail); tiny row counts keep the
    // suite fast
    c.data.n_train = 240;
    c.data.n_test = 60;
    c.workers = 4;
    c.iters = 40;
    c.batch = 40;
    c.record_every = 1;
    c.threads = threads;
    c.server_shards = shards;
    // pin the wire schedule regardless of the CI env-matrix defaults
    c.wire_mode = WireMode::Sync;
    c.staleness_bound = 0;
    c.downlink = downlink;
    c.down_bits_min = 2;
    c.down_bits_max = 8;
    c
}

/// Everything observable about a run, collected per iteration.
#[derive(Debug, PartialEq)]
struct Trace {
    // (loss, grad_norm_sq, bits, uploads, max_eps_sq) per step
    steps: Vec<(f64, f64, u64, usize, f64)>,
    rounds: u64,
    up_bits: u64,
    down_bits: u64,
    down_msgs: u64,
    sim_time: f64,
    theta: Vec<f32>,
    worker_theta: Vec<f32>,
    /// per-step snapshot of the chosen downlink shard widths
    widths: Vec<Vec<u32>>,
}

fn run_trace(cfg: &RunCfg) -> Trace {
    let mut t = laq::algo::build_native(cfg).unwrap();
    let mut steps = Vec::with_capacity(cfg.iters);
    let mut widths = Vec::with_capacity(cfg.iters);
    for _ in 0..cfg.iters {
        let s = t.step().unwrap();
        steps.push((s.loss, s.grad_norm_sq, s.bits, s.uploads, s.max_eps_sq));
        widths.push(t.downlink_widths().to_vec());
    }
    Trace {
        steps,
        rounds: t.net.uplink_rounds(),
        up_bits: t.net.uplink_bits(),
        down_bits: t.net.downlink_bits(),
        down_msgs: t.net.downlink_msgs(),
        sim_time: t.net.sim_time(),
        theta: t.theta().to_vec(),
        worker_theta: t.worker_theta().to_vec(),
        widths,
    }
}

#[test]
fn exact_downlink_broadcasts_theta_verbatim_and_bills_dense_bits() {
    let cfg = cfg_for(DownlinkMode::Exact, 1, 1);
    let mut t = laq::algo::build_native(&cfg).unwrap();
    let dim = t.theta().len();
    for k in 1..=10u64 {
        t.step().unwrap();
        // the worker view IS the server θ, and every round bills one
        // raw-IEEE broadcast — today's behavior, exactly
        assert_eq!(t.worker_theta(), t.theta(), "round {k}");
        assert_eq!(t.net.downlink_bits(), k * 32 * dim as u64);
        assert_eq!(t.net.downlink_msgs(), k);
    }
}

#[test]
fn quantized_downlink_matches_exact_final_loss_on_strictly_fewer_total_bits() {
    // the acceptance criterion: same iteration horizon on strongly
    // convex logreg, final loss within 5%, strictly fewer TOTAL bits
    let mut exact = cfg_for(DownlinkMode::Exact, 1, 1);
    exact.iters = 240;
    let e = run_trace(&exact);

    let mut quant = cfg_for(DownlinkMode::Quantized, 1, 1);
    quant.iters = 240;
    let q = run_trace(&quant);

    assert_eq!(e.steps.len(), q.steps.len());
    let first = e.steps.first().unwrap().0;
    let le = e.steps.last().unwrap().0;
    let lq = q.steps.last().unwrap().0;
    assert!(le < 0.8 * first, "exact run did not contract ({first} -> {le})");
    assert!(lq < 0.8 * first, "quantized run did not contract ({first} -> {lq})");
    assert!(
        (lq - le).abs() <= 0.05 * le.abs().max(1e-9),
        "quantized-downlink final loss {lq} strays from exact {le} beyond 5%"
    );
    assert!(
        q.up_bits + q.down_bits < e.up_bits + e.down_bits,
        "quantized moved {} total bits vs exact {} — no saving",
        q.up_bits + q.down_bits,
        e.up_bits + e.down_bits
    );
    // and the saving is genuinely a downlink saving
    assert!(q.down_bits < e.down_bits);
}

#[test]
fn quantized_downlink_trace_is_pure_across_threads_and_shards() {
    let base = run_trace(&cfg_for(DownlinkMode::Quantized, 1, 1));
    // the downlink shard grid is the fixed DELTA_BLOCK partition, so the
    // wall-clock knobs must not perturb a single bit of the trace
    for (threads, shards) in [(1usize, 7usize), (4, 1), (4, 7)] {
        let t = run_trace(&cfg_for(DownlinkMode::Quantized, threads, shards));
        assert_eq!(
            base, t,
            "quantized downlink threads={threads} shards={shards} not reproducible"
        );
    }
    let again = run_trace(&cfg_for(DownlinkMode::Quantized, 4, 7));
    assert_eq!(base, again, "quantized downlink rerun diverged");
    // the schedule must have actually dialed somewhere below the ceiling
    // at least once, or the purity claim is vacuous
    let min_width = base.widths.iter().flatten().copied().filter(|&w| w > 0).min();
    assert!(min_width.is_some(), "no downlink widths recorded");
}

#[test]
fn quantized_downlink_composes_with_the_async_wire_phases() {
    for (wire, staleness) in [(WireMode::Async, 2usize), (WireMode::AsyncCross, 2)] {
        let mut base_cfg = cfg_for(DownlinkMode::Quantized, 1, 1);
        base_cfg.wire_mode = wire;
        base_cfg.staleness_bound = staleness;
        let base = run_trace(&base_cfg);
        for (threads, shards) in [(4usize, 1usize), (4, 7)] {
            let mut cfg = base_cfg.clone();
            cfg.threads = threads;
            cfg.server_shards = shards;
            let t = run_trace(&cfg);
            assert_eq!(
                base,
                t,
                "{} quantized downlink threads={threads} shards={shards} not reproducible",
                wire.name()
            );
        }
    }
}

#[test]
fn quantized_downlink_accounting_is_exact_per_round() {
    let cfg = cfg_for(DownlinkMode::Quantized, 1, 1);
    let mut t = laq::algo::build_native(&cfg).unwrap();
    let dim = t.theta().len();
    let n_shards = dim.div_ceil(DELTA_BLOCK);

    // round 0 primes the mirror with one exact broadcast
    t.step().unwrap();
    assert_eq!(t.net.downlink_bits(), 32 * dim as u64);
    assert_eq!(t.net.downlink_msgs(), 1);

    // afterwards every round's charge is the sum of the per-shard framed
    // sections, billed as ONE broadcast message
    for k in 2..=12u64 {
        let before = t.net.downlink_bits();
        t.step().unwrap();
        let widths = t.downlink_widths().to_vec();
        assert_eq!(widths.len(), n_shards);
        let mut expect = 0u64;
        for (s, &w) in widths.iter().enumerate() {
            assert!(
                (cfg.down_bits_min..=cfg.down_bits_max).contains(&w),
                "round {k} shard {s} width {w} outside [{}, {}]",
                cfg.down_bits_min,
                cfg.down_bits_max
            );
            let p_s = DELTA_BLOCK.min(dim - s * DELTA_BLOCK);
            expect += 32 + 8 + (w as u64) * p_s as u64;
        }
        assert_eq!(
            t.net.downlink_bits() - before,
            expect,
            "round {k} downlink charge mismatch"
        );
        assert_eq!(t.net.downlink_msgs(), k, "one broadcast message per round");
    }
}

#[test]
fn run_result_totals_split_by_direction() {
    for mode in [DownlinkMode::Exact, DownlinkMode::Quantized] {
        let mut t = laq::algo::build_native(&cfg_for(mode, 1, 1)).unwrap();
        let res = t.run().unwrap();
        assert_eq!(res.total_bits, res.uplink_bits + res.downlink_bits);
        assert_eq!(res.uplink_bits, t.net.uplink_bits());
        assert_eq!(res.downlink_bits, t.net.downlink_bits());
        assert!(res.downlink_bits > 0, "{}: downlink never billed", mode.name());
        // the trace's cumulative downlink column ends at the total
        assert_eq!(res.trace.last().unwrap().down_bits, res.downlink_bits);
    }
}

#[test]
fn worker_view_tracks_theta_within_the_grid() {
    let cfg = cfg_for(DownlinkMode::Quantized, 1, 1);
    let mut t = laq::algo::build_native(&cfg).unwrap();
    for _ in 0..30 {
        t.step().unwrap();
    }
    // the mirror recursion quantizes each round's θ-delta, so the view
    // error is a fraction (τ ≤ 1/3 at the 2-bit floor) of the per-round
    // movement — far smaller than θ itself.  A loose end-to-end bound:
    let inf: f32 = t
        .theta()
        .iter()
        .zip(t.worker_theta())
        .map(|(a, b)| (a - b).abs())
        .fold(0.0, f32::max);
    let scale: f32 = t.theta().iter().map(|v| v.abs()).fold(0.0, f32::max);
    assert!(
        inf <= 0.05 * scale.max(1e-3),
        "worker θ view drifted: ‖θ − θ̂‖∞ = {inf} vs ‖θ‖∞ = {scale}"
    );
}

#[test]
fn checkpoint_v5_resumes_the_quantized_downlink_bit_exactly() {
    let dir = std::env::temp_dir().join("laq_downlink_ckpt");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("mid.ckpt");

    let cfg = cfg_for(DownlinkMode::Quantized, 1, 1);

    // uninterrupted reference run
    let mut straight = laq::algo::build_native(&cfg).unwrap();
    for _ in 0..30 {
        straight.step().unwrap();
    }

    let mut first = laq::algo::build_native(&cfg).unwrap();
    for _ in 0..15 {
        first.step().unwrap();
    }
    first.save_checkpoint(&path).unwrap();

    // resume on a trainer configured exact — the checkpoint's recorded
    // downlink mode, width range, mirror and per-shard fold state must
    // take over (exactly like the wire and bit schedules)
    let mut resumed = laq::algo::build_native(&cfg_for(DownlinkMode::Exact, 4, 7)).unwrap();
    resumed.load_checkpoint(&path).unwrap();
    assert_eq!(resumed.cfg.downlink, DownlinkMode::Quantized);
    assert_eq!((resumed.cfg.down_bits_min, resumed.cfg.down_bits_max), (2, 8));
    for _ in 0..15 {
        resumed.step().unwrap();
    }

    assert_eq!(straight.theta(), resumed.theta());
    assert_eq!(straight.worker_theta(), resumed.worker_theta());
    assert_eq!(straight.downlink_widths(), resumed.downlink_widths());
    let _ = std::fs::remove_dir_all(&dir);
}
