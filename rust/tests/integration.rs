//! Integration tests: full training runs exercising coordinator + comm +
//! codecs + models together on small problems.

use laq::config::{Algo, ModelKind, RunCfg};
use laq::util::stats::log_slope;

fn small_cfg(algo: Algo) -> RunCfg {
    let mut c = RunCfg::paper_logreg(algo);
    c.data.name = "ijcnn1".into();
    c.data.n_train = 400;
    c.data.n_test = 100;
    c.workers = 5;
    c.iters = 150;
    c.batch = 50;
    c.record_every = 1;
    c
}

fn run(cfg: &RunCfg) -> laq::metrics::RunResult {
    let mut t = laq::algo::build_native(cfg).unwrap();
    t.run().unwrap()
}

#[test]
fn all_eight_algorithms_converge() {
    for algo in Algo::all() {
        let mut cfg = small_cfg(algo);
        if algo.is_stochastic() {
            cfg.alpha = 0.01;
        }
        let res = run(&cfg);
        let first = res.trace.first().unwrap().loss;
        let last = res.final_loss();
        assert!(
            last < 0.8 * first,
            "{}: {first} -> {last}",
            algo.name()
        );
        assert!(res.final_accuracy.unwrap() > 0.75, "{}", algo.name());
    }
}

#[test]
fn laq_converges_linearly_on_strongly_convex_loss() {
    // Theorem 1: linear rate — the log-residual slope must be clearly
    // negative and roughly constant (geometric decay)
    let mut cfg = small_cfg(Algo::Laq);
    cfg.iters = 400;
    let res = run(&cfg);
    // estimate f* from the tail
    let fstar = res.losses().iter().cloned().fold(f64::INFINITY, f64::min);
    let resid: Vec<f64> = res
        .losses()
        .iter()
        .map(|l| l - fstar + 1e-12)
        .take(200) // early phase, before fp noise floor
        .collect();
    let slope = log_slope(&resid);
    assert!(slope < -1e-3, "log-slope {slope} not clearly negative");
}

#[test]
fn laq_saves_rounds_and_bits_vs_gd() {
    let gd = run(&small_cfg(Algo::Gd));
    let laq = run(&small_cfg(Algo::Laq));
    assert!(laq.total_rounds * 3 < gd.total_rounds);
    // the paper's "Bit #" counts worker → server transmissions, so the
    // claim is on uplink bits (both runs share the same downlink mode)
    assert!(laq.uplink_bits * 10 < gd.uplink_bits);
    // same iteration budget: final losses comparable (within 20%)
    assert!(laq.final_loss() < 1.2 * gd.final_loss());
}

#[test]
fn qgd_matches_gd_trajectory_at_high_bits() {
    // with b = 16 the quantization error is ~1e-5 relative: QGD's loss
    // curve must track GD's closely
    let gd = run(&small_cfg(Algo::Gd));
    let mut qcfg = small_cfg(Algo::Qgd);
    qcfg.bits = 16;
    let qgd = run(&qcfg);
    for (a, b) in gd.losses().iter().zip(qgd.losses()).step_by(10) {
        assert!((a - b).abs() < 5e-3 * a.max(1e-3), "{a} vs {b}");
    }
}

#[test]
fn laq_with_zero_xi_and_high_bits_tracks_gd() {
    // ξ = 0 disables the movement slack; with high b the 3(||ε||²+||ε̂||²)
    // slack is tiny, so LAQ rarely skips and behaves like GD (paper §2.3:
    // "LAQ reduces to GD")
    let gd = run(&small_cfg(Algo::Gd));
    let mut cfg = small_cfg(Algo::Laq);
    cfg.bits = 16;
    cfg.criterion.xi = vec![0.0; cfg.criterion.d];
    let laq = run(&cfg);
    let g_last = gd.final_loss();
    let l_last = laq.final_loss();
    assert!(
        (g_last - l_last).abs() < 0.02 * g_last.max(1e-6),
        "{g_last} vs {l_last}"
    );
}

#[test]
fn stochastic_laq_beats_sgd_on_communication() {
    let mut s = small_cfg(Algo::Sgd);
    s.alpha = 0.01;
    let mut q = small_cfg(Algo::Slaq);
    q.alpha = 0.01;
    let sgd = run(&s);
    let slaq = run(&q);
    assert!(slaq.uplink_bits < sgd.uplink_bits);
    assert!(slaq.total_rounds <= sgd.total_rounds);
}

#[test]
fn trace_counters_are_monotone() {
    let res = run(&small_cfg(Algo::Laq));
    let mut prev = (0u64, 0u64, 0u64, 0.0f64);
    for t in &res.trace {
        assert!(t.rounds >= prev.0);
        assert!(t.bits >= prev.1);
        assert!(t.down_bits >= prev.2);
        assert!(t.sim_time >= prev.3);
        prev = (t.rounds, t.bits, t.down_bits, t.sim_time);
    }
}

#[test]
fn sim_time_favors_lazy_methods() {
    // the latency model's point: fewer rounds -> less wall-clock
    let gd = run(&small_cfg(Algo::Gd));
    let laq = run(&small_cfg(Algo::Laq));
    assert!(laq.sim_time < gd.sim_time);
}

#[test]
fn mlp_runs_under_laq() {
    let mut cfg = small_cfg(Algo::Laq);
    cfg.model = ModelKind::Mlp;
    cfg.hidden = 8;
    cfg.bits = 8;
    cfg.iters = 60;
    let res = run(&cfg);
    let first = res.trace.first().unwrap().loss;
    assert!(res.final_loss() < first);
    assert!(res.total_rounds < (60 * 5) as u64);
}

#[test]
fn heterogeneous_sharding_trains() {
    let mut cfg = small_cfg(Algo::Laq);
    cfg.data.hetero_alpha = Some(0.2);
    let res = run(&cfg);
    let first = res.trace.first().unwrap().loss;
    assert!(res.final_loss() < first);
}

#[test]
fn config_file_roundtrip_drives_training() {
    let dir = std::env::temp_dir().join("laq_int_cfg");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("run.toml");
    std::fs::write(
        &path,
        "[run]\nalgo = \"laq\"\nworkers = 3\niters = 10\nbits = 4\n[data]\nname = \"ijcnn1\"\nn_train = 150\nn_test = 50\n",
    )
    .unwrap();
    let mut cfg = RunCfg::paper_logreg(Algo::Gd);
    cfg.load_file(path.to_str().unwrap()).unwrap();
    assert_eq!(cfg.algo, Algo::Laq);
    assert_eq!(cfg.workers, 3);
    let res = run(&cfg);
    assert_eq!(res.iters_run, 10);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn checkpoint_resume_is_bit_identical() {
    // run 30 iters straight vs 15 + checkpoint + resume 15: identical θ,
    // identical upload decisions — the mirror state survives exactly
    let cfg = small_cfg(Algo::Laq);
    let dir = std::env::temp_dir().join("laq_ckpt_int");
    let path = dir.join("mid.ckpt");

    let mut straight = laq::algo::build_native(&cfg).unwrap();
    for _ in 0..30 {
        straight.step().unwrap();
    }

    let mut first = laq::algo::build_native(&cfg).unwrap();
    for _ in 0..15 {
        first.step().unwrap();
    }
    first.save_checkpoint(&path).unwrap();
    let rounds_at_15 = first.net.uplink_rounds();

    let mut resumed = laq::algo::build_native(&cfg).unwrap();
    resumed.load_checkpoint(&path).unwrap();
    for _ in 0..15 {
        resumed.step().unwrap();
    }

    assert_eq!(straight.theta(), resumed.theta());
    // counters restart at zero on resume; decisions must still line up
    assert_eq!(
        straight.net.uplink_rounds(),
        rounds_at_15 + resumed.net.uplink_rounds()
    );
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn checkpoint_rejects_wrong_shape() {
    let cfg = small_cfg(Algo::Laq);
    let dir = std::env::temp_dir().join("laq_ckpt_int2");
    let path = dir.join("mid.ckpt");
    let mut t = laq::algo::build_native(&cfg).unwrap();
    t.step().unwrap();
    t.save_checkpoint(&path).unwrap();

    let mut other_cfg = small_cfg(Algo::Laq);
    other_cfg.data.name = "covtype".into(); // different dim (54×7)
    let mut other = laq::algo::build_native(&other_cfg).unwrap();
    assert!(other.load_checkpoint(&path).is_err());
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn efsgd_converges_and_counts_one_bit_per_coord() {
    let mut cfg = small_cfg(Algo::EfSgd);
    cfg.alpha = 0.01;
    let res = run(&cfg);
    let first = res.trace.first().unwrap().loss;
    assert!(res.final_loss() < first, "{first} -> {}", res.final_loss());
    // 44-dim problem: every upload is exactly 32 + 44 bits (uplink only —
    // the broadcast is billed separately and varies with LAQ_DOWNLINK)
    let expect = (32 + 44) as u64 * res.total_rounds;
    assert_eq!(res.uplink_bits, expect);
    assert_eq!(res.total_bits, res.uplink_bits + res.downlink_bits);
}

#[test]
fn gradnorm_criterion_mode_trains_and_skips() {
    // the optimizer-agnostic rhs (13): ||∇^{k-1}||²/(2M²) — used by the
    // transformer example under server-side Adam
    let mut cfg = small_cfg(Algo::Laq);
    cfg.criterion.mode = laq::config::CritMode::GradNorm;
    let res = run(&cfg);
    let first = res.trace.first().unwrap().loss;
    assert!(res.final_loss() < first);
    // it must actually skip some uploads
    assert!(res.total_rounds < (cfg.iters * cfg.workers) as u64);
}

#[test]
fn out_of_core_shard_training_is_bit_identical_to_in_ram() {
    // write the exact dataset a small_cfg run synthesizes to an on-disk
    // LAQSHRD1 file, then train once from RAM and once from the mmap —
    // θ and every communication counter must match bit-for-bit
    let cfg = small_cfg(Algo::Laq);
    let tt = laq::data::load(&cfg.data.name, cfg.data.n_train, cfg.data.n_test, cfg.data.seed)
        .unwrap();
    let dir = std::env::temp_dir().join("laq_ooc_int");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("ijcnn1.shard");
    laq::data::shard::write_shard(path.to_str().unwrap(), &tt).unwrap();

    // the mapped view really is the same data, zero-copy where available
    let mapped = laq::data::shard::open_shard(path.to_str().unwrap()).unwrap();
    let a: Vec<u32> = tt.train.x.iter().map(|v| v.to_bits()).collect();
    let b: Vec<u32> = mapped.train.x.iter().map(|v| v.to_bits()).collect();
    assert_eq!(a, b, "mapped features differ from the in-RAM dataset");

    let mut shard_cfg = cfg.clone();
    shard_cfg.data.name = format!("shard:{}", path.to_str().unwrap());

    let mut in_ram = laq::algo::build_native(&cfg).unwrap();
    let mut ooc = laq::algo::build_native(&shard_cfg).unwrap();
    for i in 0..40 {
        let sa = in_ram.step().unwrap();
        let sb = ooc.step().unwrap();
        assert_eq!(sa.loss.to_bits(), sb.loss.to_bits(), "loss drift at step {i}");
    }
    let ta: Vec<u32> = in_ram.theta().iter().map(|v| v.to_bits()).collect();
    let tb: Vec<u32> = ooc.theta().iter().map(|v| v.to_bits()).collect();
    assert_eq!(ta, tb, "θ drift between in-RAM and out-of-core runs");
    assert_eq!(in_ram.net.uplink_rounds(), ooc.net.uplink_rounds());
    assert_eq!(in_ram.net.uplink_bits(), ooc.net.uplink_bits());
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn adam_server_opt_trains_logreg() {
    let mut cfg = small_cfg(Algo::Laq);
    cfg.criterion.mode = laq::config::CritMode::GradNorm;
    cfg.alpha = 0.003; // Adam moves ~alpha per coordinate per step
    let mut t = laq::algo::build_native(&cfg).unwrap();
    t.set_server_opt(laq::coordinator::server::ServerOpt::adam());
    let first = t.step().unwrap().loss;
    let mut last = first;
    for _ in 1..100 {
        last = t.step().unwrap().loss;
    }
    assert!(last < first, "{first} -> {last}");
}
