//! Zero-allocation steady state for the LAQ hot loop.
//!
//! A counting global allocator wraps `System`; after a warmup phase the
//! test asserts that `Trainer::step` performs **zero** heap allocations —
//! across the whole pipeline: broadcast copy, gradient evaluation
//! (retained node buffer), criterion + innovation quantization (codes
//! written into the staged payload), wire encode/decode (network-retained
//! buffers), sharded absorb + θ-update (SendPtr ranges + retained block
//! partials), and the pool dispatch itself (stack batch descriptors).
//!
//! Kept to a single #[test] so the enable/disable window can't race
//! another test in this binary.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};

struct CountingAlloc;

static ENABLED: AtomicBool = AtomicBool::new(false);
static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        if ENABLED.load(Ordering::Relaxed) {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
        }
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        if ENABLED.load(Ordering::Relaxed) {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
        }
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        if ENABLED.load(Ordering::Relaxed) {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
        }
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        // frees are fine in steady state (there are none on the LAQ path,
        // but the contract we pin is "no new heap memory per step")
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn laq_cfg(
    dataset: &str,
    n_train: usize,
    threads: usize,
    shards: usize,
) -> laq::config::RunCfg {
    let mut c = laq::config::RunCfg::paper_logreg(laq::config::Algo::Laq);
    c.data.name = dataset.into();
    c.data.n_train = n_train;
    c.data.n_test = 40;
    c.workers = 4;
    c.iters = 1000; // stepped manually
    c.threads = threads;
    c.server_shards = shards;
    // pin the schedule regardless of the LAQ_WIRE_MODE / LAQ_DOWNLINK env
    // defaults; the async and quantized-downlink legs below re-set these
    // explicitly
    c.wire_mode = laq::config::WireMode::Sync;
    c.staleness_bound = 0;
    c.downlink = laq::config::DownlinkMode::Exact;
    c
}

/// Warm a trainer up, then count allocations over `steps` steps.
fn count_steps(cfg: &laq::config::RunCfg, warmup: usize, steps: usize) -> u64 {
    let mut t = laq::algo::build_native(cfg).unwrap();
    for _ in 0..warmup {
        t.step().unwrap();
    }
    ALLOCS.store(0, Ordering::SeqCst);
    ENABLED.store(true, Ordering::SeqCst);
    for _ in 0..steps {
        t.step().unwrap();
    }
    ENABLED.store(false, Ordering::SeqCst);
    ALLOCS.load(Ordering::SeqCst)
}

#[test]
fn laq_step_is_allocation_free_after_warmup() {
    // sequential everything: the canonical zero-alloc pin.
    // ijcnn1-like keeps rows/worker below the model layer's chunk-parallel
    // threshold, so the gradient runs on retained buffers.
    let seq = laq_cfg("ijcnn1", 200, 1, 1);
    let n = count_steps(&seq, 30, 40);
    assert_eq!(n, 0, "sequential LAQ step allocated {n} times after warmup");

    // both fan-outs live: worker pool + sharded server at mnist dims
    // (p = 7840 ⇒ real multi-shard plan).  The pool dispatch uses stack
    // batch descriptors + futex waits, so this is allocation-free too.
    let par = laq_cfg("mnist", 240, 2, 2);
    let n = count_steps(&par, 30, 40);
    assert_eq!(n, 0, "sharded/threaded LAQ step allocated {n} times after warmup");

    // LAG rides the same lazy path with the exact codec (staged dense
    // payload, no to_vec per refresh)
    let mut lag = laq_cfg("ijcnn1", 200, 1, 1);
    lag.algo = laq::config::Algo::Lag;
    let n = count_steps(&lag, 30, 40);
    assert_eq!(n, 0, "sequential LAG step allocated {n} times after warmup");

    // chunk-parallel gradient path: 300 rows/worker clears the model
    // layer's PAR_THRESHOLD, so the full gradient fans out over the
    // global pool — the chunk partials must land in the worker-retained
    // scratch, not per-chunk fresh vectors (that was the last steady-state
    // allocation the PR 2 pin missed)
    let big = laq_cfg("mnist", 1200, 1, 1);
    let n = count_steps(&big, 5, 10);
    assert_eq!(n, 0, "chunk-parallel LAQ step allocated {n} times after warmup");

    // SLAQ: the per-step minibatch draw now refills the trainer's
    // retained rows buffers (Batcher::next_batch_into over its identity
    // pool) instead of allocating a fresh index vector per worker
    let mut slaq = laq_cfg("ijcnn1", 200, 1, 1);
    slaq.algo = laq::config::Algo::Slaq;
    slaq.batch = 80; // 20 rows/worker (shards hold 50)
    let n = count_steps(&slaq, 30, 40);
    assert_eq!(n, 0, "SLAQ step allocated {n} times after warmup");

    // async wire path: the worker fan-out now posts through a retained
    // StreamBatch (no per-step descriptor box) and the pipelined
    // absorber's mirror base pointers refill a server-retained scratch —
    // the whole three-lane pipeline is allocation-free, at staleness 0
    // (bit-identical-to-sync schedule) and under genuine reordering
    for staleness in [0usize, 2] {
        let mut a = laq_cfg("mnist", 240, 2, 2);
        a.wire_mode = laq::config::WireMode::Async;
        a.staleness_bound = staleness;
        let n = count_steps(&a, 30, 40);
        assert_eq!(
            n, 0,
            "async(staleness={staleness}) LAQ step allocated {n} times after warmup"
        );
    }

    // adaptive bit schedule: per-(worker, round) widths ride the framed
    // self-describing wire layout through the same retained buffers
    // (enc scratch pre-sized for bits_max + the width field, codes/rx
    // reused across width changes) and the schedule fold is plain
    // arithmetic on retained per-worker state — still zero allocations
    for (threads, shards) in [(1usize, 1usize), (2, 2)] {
        let mut ad = laq_cfg("mnist", 240, threads, shards);
        ad.bit_schedule = laq::config::BitScheduleKind::Innovation;
        ad.bits_min = 2;
        ad.bits_max = 4;
        let n = count_steps(&ad, 30, 40);
        assert_eq!(
            n, 0,
            "adaptive-width ({threads}x{shards}) LAQ step allocated {n} times after warmup"
        );
    }

    // quantized θ broadcast: the downlink encoder reuses the staged
    // innovation payload (codes scratch pre-sized for one DELTA_BLOCK
    // shard), the wire round-trips through the pre-warmed framed downlink
    // slot, and the worker view refills `theta_bc` in place — per-step
    // allocations stay at zero with the broadcast compressed, sequential
    // and with both fan-outs live (mnist p = 7840 ⇒ 8 downlink shards)
    for (threads, shards) in [(1usize, 1usize), (2, 2)] {
        let mut dq = laq_cfg("mnist", 240, threads, shards);
        dq.downlink = laq::config::DownlinkMode::Quantized;
        dq.down_bits_min = 2;
        dq.down_bits_max = 8;
        let n = count_steps(&dq, 30, 40);
        assert_eq!(
            n, 0,
            "quantized-downlink ({threads}x{shards}) LAQ step allocated {n} times after warmup"
        );
    }

    // quantized downlink composes with the pipelined wire phase — the
    // broadcast happens on the coordinator between rounds, outside the
    // absorb lanes, so the async engine's retained state is untouched
    let mut dqa = laq_cfg("mnist", 240, 2, 2);
    dqa.wire_mode = laq::config::WireMode::Async;
    dqa.staleness_bound = 2;
    dqa.downlink = laq::config::DownlinkMode::Quantized;
    let n = count_steps(&dqa, 30, 40);
    assert_eq!(n, 0, "async quantized-downlink LAQ step allocated {n} times after warmup");

    // cross-round staleness: deferred uploads park in pre-warmed
    // per-(worker, round) wire-slot rings and the in-flight bookkeeping
    // (lags, deadlines, pending list) refills retained buffers — still
    // zero allocations per step
    let mut x = laq_cfg("mnist", 240, 2, 2);
    x.wire_mode = laq::config::WireMode::AsyncCross;
    x.staleness_bound = 2;
    let n = count_steps(&x, 30, 40);
    assert_eq!(n, 0, "async-cross LAQ step allocated {n} times after warmup");

    // the sequential (threads=1) async-cross engine shares the same
    // retained state
    let mut xs = laq_cfg("ijcnn1", 200, 1, 1);
    xs.wire_mode = laq::config::WireMode::AsyncCross;
    xs.staleness_bound = 2;
    let n = count_steps(&xs, 30, 40);
    assert_eq!(n, 0, "sequential async-cross LAQ step allocated {n} times after warmup");
}
