//! Contracts of the async wire phase (`cfg.wire_mode`):
//!
//! * **sync regression** — `wire_mode = sync` is the pre-existing
//!   schedule; its traces must never drift.  A self-seeding golden
//!   fingerprint file pins all nine algorithms across future changes
//!   (first run records, later runs assert; it also fingerprints the
//!   async(2) and async-cross(2) engines, whose traces are equally pure
//!   functions of (seed, config)), and the sync-vs-async(0) test below
//!   ties the async engine to the same arithmetic.
//! * **degeneration** — `wire_mode = async, staleness_bound = 0` absorbs
//!   in worker index order through the pipelined machinery, so it must be
//!   **bit-identical** to sync for all nine algorithms, at any
//!   (threads, shards).
//! * **per-seed reproducibility** — with `staleness_bound > 0` the
//!   landing schedule reorders absorption, so async traces differ from
//!   sync (f32 reassociation) but are a pure function of (seed, config):
//!   identical across repeated runs and across every (threads, shards)
//!   combination.
//! * **accounting exactness** — bits, rounds, per-worker rounds and the
//!   simulated latency clock are folded on the coordinator in index
//!   order in both modes, so they match sync *exactly* even when the
//!   absorb order does not.
//! * **wire-schedule persistence** — checkpoints record
//!   (wire_mode, staleness_bound) and resume adopts them, so an async
//!   run's remaining trace replays bit-for-bit.

use laq::config::{Algo, RunCfg, WireMode};

fn cfg_for(
    algo: Algo,
    wire: WireMode,
    staleness: usize,
    threads: usize,
    shards: usize,
) -> RunCfg {
    let mut c = RunCfg::paper_logreg(algo);
    // mnist-like keeps p = 7840 (8 coordinate blocks ⇒ real shard plans);
    // tiny row counts keep the suite fast
    c.data.n_train = 240;
    c.data.n_test = 60;
    c.workers = 4;
    c.iters = 30;
    c.batch = 40;
    c.record_every = 1;
    c.threads = threads;
    c.server_shards = shards;
    c.wire_mode = wire;
    c.staleness_bound = staleness;
    // pin the downlink: the golden fingerprints below predate the
    // quantized θ broadcast and must stay bit-identical under
    // `downlink = exact` whatever the CI env matrix (`LAQ_DOWNLINK`) says;
    // `rust/tests/downlink.rs` owns the quantized-downlink contracts
    c.downlink = laq::config::DownlinkMode::Exact;
    if algo.is_stochastic() {
        c.alpha = 0.01;
    }
    c
}

/// Everything observable about a run, collected per iteration.
#[derive(Debug, PartialEq)]
struct Trace {
    // (loss, grad_norm_sq, bits, uploads, max_eps_sq) per step — f64
    // compared exactly: the contracts here are bit-for-bit, not
    // approximate (except where a test says otherwise)
    steps: Vec<(f64, f64, u64, usize, f64)>,
    rounds: u64,
    bits: u64,
    sim_time: f64,
    per_worker_rounds: Vec<u64>,
    clocks: Vec<usize>,
    theta: Vec<f32>,
}

fn run_trace(cfg: &RunCfg) -> Trace {
    let mut t = laq::algo::build_native(cfg).unwrap();
    let mut steps = Vec::with_capacity(cfg.iters);
    for _ in 0..cfg.iters {
        let s = t.step().unwrap();
        steps.push((s.loss, s.grad_norm_sq, s.bits, s.uploads, s.max_eps_sq));
    }
    Trace {
        steps,
        rounds: t.net.uplink_rounds(),
        bits: t.net.uplink_bits(),
        sim_time: t.net.sim_time(),
        per_worker_rounds: t.net.per_worker_rounds().to_vec(),
        clocks: t.clocks(),
        theta: t.theta().to_vec(),
    }
}

#[test]
fn async_with_zero_staleness_is_bit_identical_to_sync() {
    for algo in Algo::all() {
        let sync = run_trace(&cfg_for(algo, WireMode::Sync, 0, 1, 1));
        for (threads, shards) in [(1usize, 1usize), (4, 7)] {
            let a = run_trace(&cfg_for(algo, WireMode::Async, 0, threads, shards));
            assert_eq!(
                sync,
                a,
                "{}: async s=0 threads={threads} shards={shards} diverged from sync",
                algo.name()
            );
        }
    }
}

#[test]
fn async_trace_is_reproducible_per_seed_across_threads_and_shards() {
    for algo in [Algo::Laq, Algo::Lag, Algo::Slaq, Algo::EfSgd] {
        let base = run_trace(&cfg_for(algo, WireMode::Async, 2, 1, 1));
        for (threads, shards) in [(1usize, 7usize), (4, 1), (4, 7)] {
            let t = run_trace(&cfg_for(algo, WireMode::Async, 2, threads, shards));
            assert_eq!(
                base,
                t,
                "{}: async s=2 threads={threads} shards={shards} not reproducible",
                algo.name()
            );
        }
        // racing schedules across two identical runs must still agree
        let again = run_trace(&cfg_for(algo, WireMode::Async, 2, 4, 7));
        assert_eq!(base, again, "{}: async rerun diverged", algo.name());
    }
}

#[test]
fn async_accounting_is_exactly_sync_accounting() {
    // staleness > 0 reorders the f32 absorbs, so losses/θ may drift — but
    // bits, rounds and the latency clock are pure per-message accounting
    // folded in index order, and must match sync bit-for-bit.  QGD makes
    // the comparison airtight: every worker uploads every round (forced),
    // so the message sequence cannot depend on the perturbed trajectory.
    let sync = run_trace(&cfg_for(Algo::Qgd, WireMode::Sync, 0, 1, 1));
    let asy = run_trace(&cfg_for(Algo::Qgd, WireMode::Async, 3, 4, 7));
    assert_eq!(sync.rounds, asy.rounds);
    assert_eq!(sync.bits, asy.bits);
    assert_eq!(sync.per_worker_rounds, asy.per_worker_rounds);
    assert_eq!(sync.sim_time.to_bits(), asy.sim_time.to_bits());
}

#[test]
fn async_reordering_stays_close_to_sync() {
    // with a non-trivial staleness bound the aggregate sums reassociate;
    // the optimization trajectory must stay within a loose tolerance
    let sync = run_trace(&cfg_for(Algo::Laq, WireMode::Sync, 0, 1, 1));
    let asy = run_trace(&cfg_for(Algo::Laq, WireMode::Async, 3, 4, 7));
    let ls = sync.steps.last().unwrap().0;
    let la = asy.steps.last().unwrap().0;
    assert!(
        (ls - la).abs() <= 1e-2 * ls.abs().max(1.0),
        "final loss diverged: sync {ls} vs async {la}"
    );
}

#[test]
fn checkpoint_persists_and_replays_the_wire_schedule() {
    let dir = std::env::temp_dir().join("laq_wire_ckpt");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("mid.ckpt");

    // uninterrupted async reference run
    let mut straight =
        laq::algo::build_native(&cfg_for(Algo::Laq, WireMode::Async, 2, 1, 1)).unwrap();
    for _ in 0..20 {
        straight.step().unwrap();
    }

    let mut first =
        laq::algo::build_native(&cfg_for(Algo::Laq, WireMode::Async, 2, 1, 1)).unwrap();
    for _ in 0..10 {
        first.step().unwrap();
    }
    first.save_checkpoint(&path).unwrap();

    // resume on a trainer configured sync — the checkpoint's recorded
    // schedule must take over (and with it, the landing order)
    let mut resumed =
        laq::algo::build_native(&cfg_for(Algo::Laq, WireMode::Sync, 0, 4, 7)).unwrap();
    resumed.load_checkpoint(&path).unwrap();
    assert_eq!(resumed.cfg.wire_mode, WireMode::Async);
    assert_eq!(resumed.cfg.staleness_bound, 2);
    for _ in 0..10 {
        resumed.step().unwrap();
    }

    assert_eq!(straight.theta(), resumed.theta());
    let _ = std::fs::remove_dir_all(&dir);
}

// --- sync golden fingerprints --------------------------------------------

fn fnv1a(h: u64, v: u64) -> u64 {
    let mut h = h;
    for b in v.to_le_bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

fn fingerprint(t: &Trace) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for s in &t.steps {
        h = fnv1a(h, s.0.to_bits());
        h = fnv1a(h, s.1.to_bits());
        h = fnv1a(h, s.2);
        h = fnv1a(h, s.3 as u64);
        h = fnv1a(h, s.4.to_bits());
    }
    h = fnv1a(h, t.rounds);
    h = fnv1a(h, t.bits);
    h = fnv1a(h, t.sim_time.to_bits());
    for &r in &t.per_worker_rounds {
        h = fnv1a(h, r);
    }
    for &c in &t.clocks {
        h = fnv1a(h, c as u64);
    }
    for &x in &t.theta {
        h = fnv1a(h, x.to_bits() as u64);
    }
    h
}

/// Cross-PR regression guard for the deterministic wire schedules: the
/// first run in a fresh checkout records `tests/golden_sync_traces.txt`;
/// every later run (including the CI matrix's other env legs) must
/// reproduce it bit-for-bit.  Covers the sync schedule AND the async /
/// async-cross engines at staleness 2 — the reordered/deferred traces
/// are pure functions of (seed, config), so they fingerprint just as
/// stably as sync's.  On mismatch the assert names the diverged lines
/// and prints the regeneration recipe instead of dumping two blobs.
#[test]
fn wire_trace_fingerprints_are_stable() {
    let path =
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/golden_sync_traces.txt");
    let mut lines = Vec::new();
    for (label, wire, staleness) in [
        ("sync", WireMode::Sync, 0usize),
        ("async2", WireMode::Async, 2),
        ("async-cross2", WireMode::AsyncCross, 2),
    ] {
        for algo in Algo::all() {
            let t = run_trace(&cfg_for(algo, wire, staleness, 1, 1));
            lines.push(format!("{label} {} {:016x}", algo.name(), fingerprint(&t)));
        }
    }
    let current = lines.join("\n") + "\n";
    match std::fs::read_to_string(&path) {
        Ok(golden) => {
            if golden != current {
                let mut diverged = Vec::new();
                let (old, new): (Vec<&str>, Vec<&str>) =
                    (golden.lines().collect(), current.lines().collect());
                for i in 0..old.len().max(new.len()) {
                    let o = old.get(i).copied().unwrap_or("<missing>");
                    let n = new.get(i).copied().unwrap_or("<missing>");
                    if o != n {
                        diverged.push(format!("  line {}: recorded `{o}` vs current `{n}`", i + 1));
                    }
                }
                panic!(
                    "wire traces diverged from the recorded goldens in {}:\n{}\n\
                     If this change is intentional (an algorithm/schedule/codec\n\
                     change that legitimately moves the traces), regenerate with:\n\
                     \n    rm {}\n    cargo test -q wire_trace_fingerprints\n\
                     \nand call the re-seed out in the PR description.",
                    path.display(),
                    diverged.join("\n"),
                    path.display(),
                );
            }
        }
        Err(_) => std::fs::write(&path, &current).expect("seed the golden trace file"),
    }
}
