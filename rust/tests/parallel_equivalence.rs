//! Cross-algorithm determinism: the trainer's parallel local phase
//! (`threads = 4`) must be **bit-for-bit** indistinguishable from the
//! sequential schedule (`threads = 1`) — same losses, same uplink bits
//! and rounds, same simulated time, same final θ.  This is the contract
//! the two-phase step refactor makes true by construction:
//!
//! * all per-worker randomness is counter-based (`Rng::stream(seed, m, k)`),
//!   a pure function of run seed, worker index and iteration — no shared
//!   generator whose draw order depends on scheduling;
//! * every upload passes through `Network::upload` in worker index order
//!   during the sequential wire phase, so accounting and the latency
//!   clock cannot observe thread interleaving;
//! * floating-point reductions (loss sum, gradient-norm accumulation,
//!   server absorbs) all run on the coordinator thread in index order.

use laq::config::{Algo, RunCfg};

fn cfg_for(algo: Algo, threads: usize) -> RunCfg {
    let mut c = RunCfg::paper_logreg(algo);
    c.data.name = "ijcnn1".into();
    c.data.n_train = 240;
    c.data.n_test = 60;
    c.workers = 4;
    c.iters = 40;
    c.batch = 40;
    c.record_every = 1;
    c.threads = threads;
    if algo.is_stochastic() {
        c.alpha = 0.01;
    }
    c
}

/// Everything observable about a run, collected per iteration.
#[derive(Debug, PartialEq)]
struct Trace {
    // (loss, grad_norm_sq, bits, uploads, max_eps_sq) per step — f64
    // compared exactly: the contract is bit-for-bit, not approximate
    steps: Vec<(f64, f64, u64, usize, f64)>,
    rounds: u64,
    bits: u64,
    sim_time: f64,
    per_worker_rounds: Vec<u64>,
    clocks: Vec<usize>,
    theta: Vec<f32>,
}

fn run_trace(cfg: &RunCfg) -> Trace {
    let mut t = laq::algo::build_native(cfg).unwrap();
    let mut steps = Vec::with_capacity(cfg.iters);
    for _ in 0..cfg.iters {
        let s = t.step().unwrap();
        steps.push((s.loss, s.grad_norm_sq, s.bits, s.uploads, s.max_eps_sq));
    }
    Trace {
        steps,
        rounds: t.net.uplink_rounds(),
        bits: t.net.uplink_bits(),
        sim_time: t.net.sim_time(),
        per_worker_rounds: t.net.per_worker_rounds().to_vec(),
        clocks: t.clocks(),
        theta: t.theta().to_vec(),
    }
}

#[test]
fn all_nine_algorithms_are_schedule_independent() {
    for algo in Algo::all() {
        let seq = run_trace(&cfg_for(algo, 1));
        let par = run_trace(&cfg_for(algo, 4));
        assert_eq!(
            seq, par,
            "{}: threads=4 trace diverged from threads=1",
            algo.name()
        );
    }
}

#[test]
fn auto_thread_count_matches_sequential() {
    // threads = 0 resolves to available_parallelism — whatever that is on
    // the host, the trace must not change
    let seq = run_trace(&cfg_for(Algo::Laq, 1));
    let auto = run_trace(&cfg_for(Algo::Laq, 0));
    assert_eq!(seq, auto);
}

#[test]
fn oversized_pool_matches_sequential() {
    // more threads than workers: the pool is capped at the worker count
    // and idle capacity must not perturb anything
    let seq = run_trace(&cfg_for(Algo::Slaq, 1));
    let par = run_trace(&cfg_for(Algo::Slaq, 16));
    assert_eq!(seq, par);
}

#[test]
fn parallel_run_is_itself_deterministic() {
    // two parallel runs with racing schedules still agree exactly
    let a = run_trace(&cfg_for(Algo::Qsgd, 4));
    let b = run_trace(&cfg_for(Algo::Qsgd, 4));
    assert_eq!(a, b);
}

#[test]
fn mlp_model_is_schedule_independent_too() {
    // the nonconvex path adds the model layer's own chunk-parallel
    // gradient evaluation nested inside the worker fan-out
    let mut c1 = cfg_for(Algo::Laq, 1);
    let mut c4 = cfg_for(Algo::Laq, 4);
    for c in [&mut c1, &mut c4] {
        c.model = laq::config::ModelKind::Mlp;
        c.hidden = 8;
        c.bits = 8;
        c.iters = 15;
    }
    assert_eq!(run_trace(&c1), run_trace(&c4));
}

#[test]
fn checkpoint_resume_crosses_thread_counts() {
    // a checkpoint written by a sequential run resumes bit-identically
    // under the parallel schedule — mirrors/clocks carry over exactly
    let dir = std::env::temp_dir().join("laq_par_ckpt");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("mid.ckpt");

    let mut straight = laq::algo::build_native(&cfg_for(Algo::Laq, 1)).unwrap();
    for _ in 0..30 {
        straight.step().unwrap();
    }

    let mut first = laq::algo::build_native(&cfg_for(Algo::Laq, 1)).unwrap();
    for _ in 0..15 {
        first.step().unwrap();
    }
    first.save_checkpoint(&path).unwrap();

    let mut resumed = laq::algo::build_native(&cfg_for(Algo::Laq, 4)).unwrap();
    resumed.load_checkpoint(&path).unwrap();
    for _ in 0..15 {
        resumed.step().unwrap();
    }

    assert_eq!(straight.theta(), resumed.theta());
    let _ = std::fs::remove_dir_all(&dir);
}
