//! Sharded-server determinism: a trainer with `server_shards = S` must be
//! **bit-for-bit** indistinguishable from `server_shards = 1` — same
//! losses, same uplink bits and rounds, same skip decisions, same
//! simulated time, same final θ.  This is the contract the sharded server
//! makes true by construction:
//!
//! * the innovation codec is coordinate-local, so absorb (dequantize +
//!   aggregate-delta + mirror-commit) is exact under any contiguous
//!   partition of `0..p`;
//! * the single cross-coordinate reduction on the hot path, `||Δθ||²`,
//!   uses a fixed DELTA_BLOCK-aligned reduction tree whose f64 sum order
//!   is independent of the shard count (see `coordinator/server.rs`);
//! * shard jobs mutate disjoint coordinate ranges, and the per-shard
//!   fan-out happens strictly inside each absorb/apply call, so the wire
//!   phase ordering (and therefore all accounting) is untouched.
//!
//! The suite mirrors `parallel_equivalence.rs` but sweeps the *server*
//! axis, uses mnist-like dims (p = 7840 ⇒ 8 coordinate blocks, so shard
//! plans 2/7/16 are genuinely distinct), and crosses shards × threads.

use laq::config::{Algo, RunCfg};

fn cfg_for(algo: Algo, shards: usize, threads: usize) -> RunCfg {
    let mut c = RunCfg::paper_logreg(algo);
    // mnist-like keeps p = 7840 (784 features × 10 classes): 8 blocks,
    // so non-trivial shard plans; tiny row counts keep the suite fast
    c.data.n_train = 240;
    c.data.n_test = 60;
    c.workers = 4;
    c.iters = 30;
    c.batch = 40;
    c.record_every = 1;
    c.threads = threads;
    c.server_shards = shards;
    if algo.is_stochastic() {
        c.alpha = 0.01;
    }
    c
}

/// Everything observable about a run, collected per iteration.
#[derive(Debug, PartialEq)]
struct Trace {
    // (loss, grad_norm_sq, bits, uploads, max_eps_sq) per step — f64
    // compared exactly: the contract is bit-for-bit, not approximate
    steps: Vec<(f64, f64, u64, usize, f64)>,
    rounds: u64,
    bits: u64,
    sim_time: f64,
    per_worker_rounds: Vec<u64>,
    clocks: Vec<usize>,
    theta: Vec<f32>,
}

fn run_trace(cfg: &RunCfg) -> Trace {
    let mut t = laq::algo::build_native(cfg).unwrap();
    let mut steps = Vec::with_capacity(cfg.iters);
    for _ in 0..cfg.iters {
        let s = t.step().unwrap();
        steps.push((s.loss, s.grad_norm_sq, s.bits, s.uploads, s.max_eps_sq));
    }
    Trace {
        steps,
        rounds: t.net.uplink_rounds(),
        bits: t.net.uplink_bits(),
        sim_time: t.net.sim_time(),
        per_worker_rounds: t.net.per_worker_rounds().to_vec(),
        clocks: t.clocks(),
        theta: t.theta().to_vec(),
    }
}

#[test]
fn all_nine_algorithms_are_shard_count_independent() {
    for algo in Algo::all() {
        let base = run_trace(&cfg_for(algo, 1, 1));
        for shards in [2usize, 7, 16] {
            let sharded = run_trace(&cfg_for(algo, shards, 1));
            assert_eq!(
                base,
                sharded,
                "{}: server_shards={shards} trace diverged from shards=1",
                algo.name()
            );
        }
    }
}

#[test]
fn auto_shard_count_matches_single_shard() {
    // shards = 0 resolves to available_parallelism — whatever that is on
    // the host, the trace must not change
    let base = run_trace(&cfg_for(Algo::Laq, 1, 1));
    let auto = run_trace(&cfg_for(Algo::Laq, 0, 1));
    assert_eq!(base, auto);
}

#[test]
fn shards_cross_threads_match_fully_sequential() {
    // both fan-outs at once: worker pool (threads=4) and shard pool
    // (shards=7) against the fully sequential run
    for algo in [Algo::Laq, Algo::Lag, Algo::Slaq] {
        let seq = run_trace(&cfg_for(algo, 1, 1));
        let par = run_trace(&cfg_for(algo, 7, 4));
        assert_eq!(
            seq,
            par,
            "{}: shards=7 × threads=4 diverged from 1 × 1",
            algo.name()
        );
    }
}

#[test]
fn sharded_run_is_itself_deterministic() {
    // two sharded runs with racing shard schedules still agree exactly
    let a = run_trace(&cfg_for(Algo::Laq, 7, 4));
    let b = run_trace(&cfg_for(Algo::Laq, 7, 4));
    assert_eq!(a, b);
}

#[test]
fn adam_server_is_shard_count_independent() {
    // the Adam θ-update shards over m/v state too; its ||Δθ||² uses the
    // same block reduction
    let run = |shards: usize| {
        let cfg = cfg_for(Algo::Laq, shards, 1);
        let mut t = laq::algo::build_native(&cfg).unwrap();
        t.set_server_opt(laq::coordinator::server::ServerOpt::adam());
        let mut steps = Vec::new();
        for _ in 0..cfg.iters {
            let s = t.step().unwrap();
            steps.push((s.loss, s.bits, s.uploads));
        }
        (steps, t.theta().to_vec())
    };
    let base = run(1);
    for shards in [2usize, 16] {
        assert_eq!(base, run(shards), "adam diverged at {shards} shards");
    }
}

#[test]
fn aggregate_invariant_holds_under_sharding() {
    // the streaming invariant check agrees with the sharded absorb path
    let cfg = cfg_for(Algo::Laq, 7, 1);
    let mut t = laq::algo::build_native(&cfg).unwrap();
    for _ in 0..10 {
        t.step().unwrap();
        assert!(t.aggregate_drift() < 1e-4, "drift {}", t.aggregate_drift());
    }
}

#[test]
fn checkpoint_resume_crosses_shard_counts() {
    // a checkpoint written by a single-shard run resumes bit-identically
    // under a sharded server (and vice versa) — checkpoints capture flat
    // algorithm state only, never the runtime topology
    let dir = std::env::temp_dir().join("laq_shard_ckpt");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("mid.ckpt");

    let mut straight = laq::algo::build_native(&cfg_for(Algo::Laq, 1, 1)).unwrap();
    for _ in 0..20 {
        straight.step().unwrap();
    }

    let mut first = laq::algo::build_native(&cfg_for(Algo::Laq, 1, 1)).unwrap();
    for _ in 0..10 {
        first.step().unwrap();
    }
    first.save_checkpoint(&path).unwrap();

    let mut resumed = laq::algo::build_native(&cfg_for(Algo::Laq, 7, 4)).unwrap();
    resumed.load_checkpoint(&path).unwrap();
    for _ in 0..10 {
        resumed.step().unwrap();
    }

    assert_eq!(straight.theta(), resumed.theta());
    let _ = std::fs::remove_dir_all(&dir);
}
