//! Loopback contract tests for the real TCP transport.
//!
//! Spawns the release `laq-server` binary plus M `laq-worker` processes
//! on `127.0.0.1:0` (ephemeral port, parsed from the server's
//! `LISTENING` line), trains strongly convex logistic regression, and
//! checks the bounded-staleness contract against an in-process
//! simulated run:
//!
//!   (1) observed `max_lag` never exceeds the configured bound;
//!   (2) per-direction bit accounting equals the bytes actually framed
//!       on the wire (the server cross-checks its counters against each
//!       worker's `Bye` counters and reports `bytes_verified`);
//!   (3) the final loss lands within the same tolerance band
//!       `tests/staleness_contract.rs` uses for the in-memory
//!       async-cross runs — `tol = 0.04 * (1 + bound)` relative to the
//!       synchronous baseline;
//!   (4) a worker process killed mid-run is retired through the
//!       `[resilience]` miss/demote path instead of wedging the fleet,
//!       and a replacement process with the same `--worker` index is
//!       re-admitted and primed with exactly one broadcast.
//!
//! Every fleet member is launched from the same config file + flags, so
//! the handshake fingerprint agrees.  Tests skip (with a logged reason)
//! when the binaries are missing — e.g. under a harness that compiled
//! only the test target.

use std::collections::HashMap;
use std::io::{BufRead, BufReader};
use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::time::{Duration, Instant};

use laq::config::{Algo, DownlinkMode, RunCfg, WireMode};

const SERVER_BIN: &str = env!("CARGO_BIN_EXE_laq-server");
const WORKER_BIN: &str = env!("CARGO_BIN_EXE_laq-worker");

/// Both transport binaries, or `None` (with a logged reason) when the
/// harness didn't build them.
fn bins() -> Option<(&'static str, &'static str)> {
    if Path::new(SERVER_BIN).exists() && Path::new(WORKER_BIN).exists() {
        Some((SERVER_BIN, WORKER_BIN))
    } else {
        eprintln!(
            "skipping transport loopback test: laq-server/laq-worker not built \
             (expected at {SERVER_BIN} and {WORKER_BIN}; run `cargo build --bins`)"
        );
        None
    }
}

// ---- process plumbing -----------------------------------------------------

/// Kills every child on drop so a failed assertion can't leak worker
/// processes into the test harness.
struct Reaper {
    children: Vec<Child>,
}

impl Reaper {
    fn new() -> Self {
        Reaper { children: Vec::new() }
    }

    fn push(&mut self, c: Child) -> usize {
        self.children.push(c);
        self.children.len() - 1
    }
}

impl Drop for Reaper {
    fn drop(&mut self) {
        for c in &mut self.children {
            let _ = c.kill();
            let _ = c.wait();
        }
    }
}

/// Shared config file: everything not expressible as a CLI flag.  The
/// same file is handed to the server and every worker, so the
/// handshake fingerprint (which covers the dataset shape) matches.
fn write_cfg(tag: &str, body: &str) -> PathBuf {
    let p = std::env::temp_dir().join(format!(
        "laq_loopback_{tag}_{}.toml",
        std::process::id()
    ));
    std::fs::write(&p, body).expect("write loopback config");
    p
}

struct FleetSpec<'a> {
    cfg_path: &'a Path,
    workers: usize,
    iters: usize,
    bound: usize,
}

impl FleetSpec<'_> {
    fn common_flags(&self) -> Vec<String> {
        vec![
            "--config".into(),
            self.cfg_path.display().to_string(),
            "--workers".into(),
            self.workers.to_string(),
            "--iters".into(),
            self.iters.to_string(),
            "--staleness-bound".into(),
            self.bound.to_string(),
            "--io-timeout-ms".into(),
            "20000".into(),
        ]
    }

    fn spawn_server(&self) -> Child {
        Command::new(SERVER_BIN)
            .args(self.common_flags())
            .args(["--listen", "127.0.0.1:0", "--round-timeout-ms", "2000"])
            .stdout(Stdio::piped())
            .stderr(Stdio::inherit())
            .spawn()
            .expect("spawn laq-server")
    }

    fn spawn_worker(&self, addr: &str, m: usize) -> Child {
        Command::new(WORKER_BIN)
            .args(self.common_flags())
            .args(["--connect", addr, "--worker", &m.to_string()])
            .stdout(Stdio::null())
            .stderr(Stdio::inherit())
            .spawn()
            .expect("spawn laq-worker")
    }
}

/// First line of server stdout must be `LISTENING <addr>`.
fn read_listening(lines: &mut impl Iterator<Item = std::io::Result<String>>) -> String {
    for line in lines {
        let line = line.expect("read server stdout");
        if let Some(addr) = line.strip_prefix("LISTENING ") {
            return addr.trim().to_string();
        }
    }
    panic!("server exited before printing LISTENING line");
}

/// The `RESULT key=value ...` line, parsed.
struct ResultLine(HashMap<String, String>);

impl ResultLine {
    fn parse(line: &str) -> Self {
        let mut kv = HashMap::new();
        for tok in line.split_whitespace().skip(1) {
            if let Some((k, v)) = tok.split_once('=') {
                kv.insert(k.to_string(), v.to_string());
            }
        }
        ResultLine(kv)
    }

    fn u(&self, key: &str) -> u64 {
        self.0
            .get(key)
            .unwrap_or_else(|| panic!("RESULT missing {key}"))
            .parse()
            .unwrap_or_else(|_| panic!("RESULT {key} not an integer"))
    }

    fn f(&self, key: &str) -> f64 {
        self.0
            .get(key)
            .unwrap_or_else(|| panic!("RESULT missing {key}"))
            .parse()
            .unwrap_or_else(|_| panic!("RESULT {key} not a number"))
    }
}

// ---- in-process baselines -------------------------------------------------

/// Contract (d) dataset from `tests/staleness_contract.rs`: strongly
/// convex regularized logreg on ijcnn1, tiny row count for speed.
fn contract_cfg(workers: usize, iters: usize) -> RunCfg {
    let mut c = RunCfg::paper_logreg(Algo::Laq);
    c.data.name = "ijcnn1".into();
    c.data.n_train = 400;
    c.data.n_test = 100;
    c.workers = workers;
    c.iters = iters;
    c.record_every = 1;
    // the CI matrix exports LAQ_DOWNLINK etc. as env defaults; the TCP
    // gate requires the exact downlink, so pin it on both sides (the
    // config file pins the subprocesses, this pins the baseline)
    c.downlink = DownlinkMode::Exact;
    c
}

const CONTRACT_TOML: &str = "[run]\ndownlink = \"exact\"\n\n\
[data]\nname = \"ijcnn1\"\nn_train = 400\nn_test = 100\n";

/// (first, last) recorded loss of the synchronous in-memory run the
/// TCP fleet must reproduce up to the staleness tolerance.
fn sim_sync_losses(mut cfg: RunCfg) -> (f64, f64) {
    cfg.wire_mode = WireMode::Sync;
    cfg.staleness_bound = 0;
    let mut t = laq::algo::build_native(&cfg).expect("build sync baseline");
    let mut first = f64::NAN;
    let mut last = f64::NAN;
    for i in 0..cfg.iters {
        let s = t.step().expect("sync baseline step");
        if i == 0 {
            first = s.loss;
        }
        last = s.loss;
    }
    (first, last)
}

// ---- healthy-fleet contract runs ------------------------------------------

/// Spawn one server + M workers, wait for RESULT, and check the full
/// contract against the in-process synchronous baseline.
fn run_contract_fleet(workers: usize, bound: usize) {
    let iters = 120;
    let cfg_path = write_cfg(&format!("m{workers}b{bound}"), CONTRACT_TOML);
    let spec = FleetSpec { cfg_path: &cfg_path, workers, iters, bound };

    let mut reap = Reaper::new();
    let mut server = spec.spawn_server();
    let stdout = server.stdout.take().expect("server stdout piped");
    reap.push(server);
    let mut lines = BufReader::new(stdout).lines();
    let addr = read_listening(&mut lines);
    for m in 0..workers {
        reap.push(spec.spawn_worker(&addr, m));
    }

    let mut result = None;
    for line in &mut lines {
        let line = line.expect("read server stdout");
        if line.starts_with("RESULT ") {
            result = Some(ResultLine::parse(&line));
            break;
        }
    }
    let r = result.expect("server exited without a RESULT line");
    drop(reap);
    let _ = std::fs::remove_file(&cfg_path);

    // protocol-level contract
    assert_eq!(r.u("rounds"), iters as u64, "fleet must finish all rounds");
    assert_eq!(r.u("workers_done"), workers as u64, "all workers complete shutdown");
    assert_eq!(r.u("retired"), 0, "healthy fleet retires nobody");
    assert_eq!(r.u("rejoined"), 0);
    assert_eq!(
        r.u("bytes_verified"),
        1,
        "billed bits must equal bytes framed on the wire (Bye cross-check)"
    );
    assert!(
        r.u("max_lag") as usize <= bound,
        "observed staleness {} exceeds bound {bound}",
        r.u("max_lag")
    );
    assert!(r.u("uplink_bits") > 0 && r.u("downlink_bits") > 0);
    assert_eq!(
        r.u("uploads") + r.u("skips"),
        (iters * workers) as u64,
        "every (round, worker) pair resolves to exactly one upload or skip"
    );

    // loss-level contract: same tolerance band as staleness_contract.rs
    // contract (d) — bounded staleness may only perturb the trajectory
    // within 4% per round of allowed lag.
    let (first, sync_last) = sim_sync_losses(contract_cfg(workers, iters));
    let last = r.f("final_loss");
    assert!(
        last.is_finite() && last < 0.8 * first,
        "TCP run failed to contract: first {first}, last {last}"
    );
    let tol = 0.04 * (1.0 + bound as f64);
    assert!(
        (last - sync_last).abs() <= tol * sync_last.abs().max(1e-9),
        "bound {bound}: TCP final loss {last} drifted beyond {tol} of sync {sync_last}"
    );
}

#[test]
fn loopback_sync_m2_matches_sim() {
    if bins().is_none() {
        return;
    }
    run_contract_fleet(2, 0);
}

#[test]
fn loopback_bounded_m4_within_contract() {
    if bins().is_none() {
        return;
    }
    run_contract_fleet(4, 2);
}

// ---- fault injection: kill a worker process mid-run -----------------------

/// Many cheap rounds (ijcnn1, p = 22) so the kill → retire → rejoin
/// sequence reliably fits inside the training horizon even on a slow
/// CI box, without making the test itself slow.
const FAULT_TOML: &str = "[run]\ndownlink = \"exact\"\n\n\
[data]\nname = \"ijcnn1\"\nn_train = 4000\nn_test = 400\n\n\
[resilience]\ncadence = 1\nmiss_threshold = 3\n";

#[test]
fn loopback_worker_death_and_rejoin() {
    if bins().is_none() {
        return;
    }
    let workers = 3;
    let iters = 600;
    let victim = 1;
    let cfg_path = write_cfg("fault", FAULT_TOML);
    let spec = FleetSpec { cfg_path: &cfg_path, workers, iters, bound: 2 };

    let mut reap = Reaper::new();
    let mut server = spec.spawn_server();
    let stdout = server.stdout.take().expect("server stdout piped");
    reap.push(server);
    let mut lines = BufReader::new(stdout).lines();
    let addr = read_listening(&mut lines);
    let mut worker_ids = Vec::new();
    for m in 0..workers {
        worker_ids.push(reap.push(spec.spawn_worker(&addr, m)));
    }

    // Drive the fault from the server's own ROUND stream: kill the
    // victim at the first observed round, then respawn a replacement
    // with the same --worker index.  A replacement that connects before
    // the server has folded the death is rejected by the handshake and
    // exits; we respawn on subsequent ROUND lines until one sticks.
    let mut killed = false;
    let mut replacement: Option<usize> = None;
    let mut respawns = 0usize;
    let start = Instant::now();
    let mut result = None;
    for line in &mut lines {
        let line = line.expect("read server stdout");
        if line.starts_with("RESULT ") {
            result = Some(ResultLine::parse(&line));
            break;
        }
        if !line.starts_with("ROUND ") {
            continue;
        }
        if !killed {
            let w = &mut reap.children[worker_ids[victim]];
            w.kill().expect("kill victim worker");
            let _ = w.wait();
            killed = true;
            continue;
        }
        // respawn (or re-respawn after a handshake rejection), capped so
        // a genuinely broken rejoin path can't loop forever
        let rejected = match replacement {
            None => true,
            Some(idx) => reap.children[idx]
                .try_wait()
                .expect("poll replacement worker")
                .is_some(),
        };
        if rejected && respawns < 20 {
            replacement = Some(reap.push(spec.spawn_worker(&addr, victim)));
            respawns += 1;
        }
        assert!(
            start.elapsed() < Duration::from_secs(300),
            "fault run exceeded its deadline"
        );
    }
    let r = result.expect("server exited without a RESULT line");
    assert!(killed, "run finished before the harness could inject the fault");
    drop(reap);
    let _ = std::fs::remove_file(&cfg_path);

    // The fleet must ride the miss/retire path, not wedge: all rounds
    // complete, the victim is retired, and the replacement is
    // re-admitted with exactly one priming broadcast.
    assert_eq!(r.u("rounds"), iters as u64, "fleet wedged after worker death");
    assert!(r.u("retired") >= 1, "killed worker was never retired");
    assert!(r.u("rejoined") >= 1, "replacement worker was never re-admitted");
    assert!(r.u("primed") >= 1, "re-admitted worker was never primed");
    assert_eq!(
        r.u("primed"),
        r.u("rejoined"),
        "membership rules: one priming broadcast per rejoin"
    );
    assert_eq!(r.u("workers_done"), workers as u64, "post-rejoin fleet is whole");
    assert!(r.u("max_lag") as usize <= 2);

    // Remaining fleet still contracts: compare against the first-round
    // loss of the equivalent in-memory run (one step is enough — the
    // objective is the same).
    let mut cfg = RunCfg::paper_logreg(Algo::Laq);
    cfg.data.name = "ijcnn1".into();
    cfg.data.n_train = 4000;
    cfg.data.n_test = 400;
    cfg.workers = workers;
    cfg.iters = 1;
    cfg.downlink = DownlinkMode::Exact;
    let (first, _) = sim_sync_losses(cfg);
    let last = r.f("final_loss");
    assert!(
        last.is_finite() && last < 0.8 * first,
        "faulted fleet failed to contract: first {first}, last {last}"
    );
}
