//! Differential kernel-test harness: every block-tiled kernel vs its
//! scalar reference twin (see `rust/src/util/kernel.rs` for the roster).
//!
//! Two layers:
//!
//! * **unit sweeps** — each twin pair called directly (no global mode
//!   flips) over adversarial shapes: empty, tile−1/tile/tile+1, p not a
//!   multiple of any tile, and `DELTA_BLOCK` boundaries.  Bit-exact
//!   where the reduction order is pinned (absorb/commit, quantize,
//!   pack/unpack, dot/axpy); ULP-bounded where the contract is weaker
//!   (gemm — though the current tiled gemm preserves the scalar
//!   reduction order exactly, so it passes at 0 ULP).
//! * **trainer sweep** — `kernels = scalar` ≡ `kernels = tiled` must be
//!   bit-identical on all nine algorithms across {1,4} threads × {1,7}
//!   shards, and the tiled sync traces must reproduce the recorded
//!   `golden_sync_traces.txt` fingerprints (seeded by
//!   `wire_equivalence.rs`) — proving the tiled rewrite never moved a
//!   golden.
//!
//! The trainer-level tests flip the process-wide kernel mode (via
//! `cfg.kernels` → `Trainer::assemble`), so they serialize on one mutex;
//! the unit sweeps call the twins directly and need no locking.

use std::sync::Mutex;

use laq::config::{Algo, RunCfg, WireMode};
use laq::coordinator::server::{
    absorb_dense_range_scalar, absorb_dense_range_tiled, absorb_fresh_range_scalar,
    absorb_fresh_range_tiled, absorb_innovation_range_scalar, absorb_innovation_range_tiled,
    DELTA_BLOCK,
};
use laq::quant::innovation::{InnovationQuantizer, QuantizedInnovation};
use laq::util::bitio::{
    pack_codes_scalar, pack_codes_tiled, unpack_codes_into_scalar, unpack_codes_into_tiled,
    BitReader, BitWriter,
};
use laq::util::kernel::KernelMode;
use laq::util::rng::Rng;
use laq::util::tensor::{
    axpy_scalar, axpy_tiled, dot_f32_scalar, dot_f32_tiled, gemm_a_bt_scalar, gemm_a_bt_tiled,
};

/// Shapes that straddle every tile boundary the kernels use: empty,
/// tile−1/tile/tile+1 for the 16-wide register tile and the 64-wide
/// dot quad-block, odd primes, and the `DELTA_BLOCK` shard boundary.
const ADVERSARIAL_P: &[usize] = &[
    0,
    1,
    2,
    15,
    16,
    17,
    37,
    63,
    64,
    65,
    100,
    503,
    DELTA_BLOCK - 1,
    DELTA_BLOCK,
    DELTA_BLOCK + 1,
];

fn vecf(seed: u64, n: usize) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    (0..n).map(|_| rng.normal() as f32).collect()
}

fn bits_of(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// Distance in units-in-the-last-place between two finite f32s.
fn ulp_diff(a: f32, b: f32) -> u64 {
    // map the IEEE754 bit patterns onto a monotone integer line
    // (negative floats sort by descending magnitude; ±0 coincide)
    fn ordered(x: f32) -> i64 {
        let b = x.to_bits();
        if b & 0x8000_0000 != 0 {
            -((b & 0x7fff_ffff) as i64)
        } else {
            b as i64
        }
    }
    (ordered(a) - ordered(b)).unsigned_abs()
}

// --- unit sweeps ----------------------------------------------------------

#[test]
fn dot_and_axpy_twins_bit_exact_over_adversarial_shapes() {
    for &p in ADVERSARIAL_P {
        let x = vecf(10 + p as u64, p);
        let y = vecf(11 + p as u64, p);
        let ds = dot_f32_scalar(&x, &y);
        let dt = dot_f32_tiled(&x, &y);
        assert_eq!(ds.to_bits(), dt.to_bits(), "dot drift at p={p}");

        let mut ys = y.clone();
        let mut yt = y.clone();
        axpy_scalar(0.37, &x, &mut ys);
        axpy_tiled(0.37, &x, &mut yt);
        assert_eq!(bits_of(&ys), bits_of(&yt), "axpy drift at p={p}");
    }
}

#[test]
fn gemm_twins_within_ulp_bound_over_adversarial_shapes() {
    // the gemm contract is ULP-bounded, not bit-pinned: a future tiled
    // gemm may re-block the k loop.  The current implementation keeps
    // the scalar reduction order, so it actually passes at 0 ULP — both
    // assertions below hold, and only the ULP one is the contract.
    for &(m, k, n) in &[
        (1usize, 1usize, 1usize),
        (3, 15, 2),
        (7, 16, 5),
        (31, 17, 7),
        (32, 64, 8),
        (33, 65, 9),
        (64, 100, 16),
        (5, 0, 3),
        (0, 4, 2),
        (3, 4, 0),
    ] {
        let a = vecf(700 + (m * k) as u64, m * k);
        let b = vecf(800 + (k * n) as u64, n * k);
        let cs = gemm_a_bt_scalar(m, k, n, &a, &b);
        let ct = gemm_a_bt_tiled(m, k, n, &a, &b);
        assert_eq!(cs.len(), ct.len(), "gemm shape ({m},{k},{n})");
        for (i, (s, t)) in cs.iter().zip(ct.iter()).enumerate() {
            assert!(
                ulp_diff(*s, *t) <= 4,
                "gemm ({m},{k},{n}) elem {i}: {s} vs {t} beyond 4 ulp"
            );
        }
        assert_eq!(bits_of(&cs), bits_of(&ct), "gemm ({m},{k},{n}) bit drift");
    }
}

#[test]
fn quantize_and_dequantize_twins_bit_exact() {
    for &p in ADVERSARIAL_P {
        for bits in [1u32, 3, 8, 16] {
            let q = InnovationQuantizer::new(bits);
            let g = vecf(20 + p as u64 + bits as u64, p);
            let qp = vecf(21 + p as u64 + bits as u64, p);
            let (mut cs, mut ct) = (Vec::new(), Vec::new());
            let mut ns = vec![0.0f32; p];
            let mut nt = vec![0.0f32; p];
            let rs = q.quantize_into_scalar(&g, &qp, &mut cs, &mut ns);
            let rt = q.quantize_into_tiled(&g, &qp, &mut ct, &mut nt);
            assert_eq!(rs.to_bits(), rt.to_bits(), "radius p={p} bits={bits}");
            assert_eq!(cs, ct, "codes p={p} bits={bits}");
            assert_eq!(bits_of(&ns), bits_of(&nt), "q_new p={p} bits={bits}");

            let qi = QuantizedInnovation { radius: rs, codes: cs, bits };
            let mut ds = vec![0.0f32; p];
            let mut dt = vec![0.0f32; p];
            q.dequantize_into_scalar(&qi, &qp, &mut ds);
            q.dequantize_into_tiled(&qi, &qp, &mut dt);
            assert_eq!(bits_of(&ds), bits_of(&dt), "dequantize p={p} bits={bits}");
        }
    }
}

#[test]
fn absorb_twins_bit_exact_including_delta_block_boundaries() {
    for &p in ADVERSARIAL_P {
        let g = vecf(30 + p as u64, p);
        let agg0 = vecf(31 + p as u64, p);
        let mir0 = vecf(32 + p as u64, p);

        let (mut ag_s, mut mi_s) = (agg0.clone(), mir0.clone());
        let (mut ag_t, mut mi_t) = (agg0.clone(), mir0.clone());
        absorb_dense_range_scalar(&g, &mut ag_s, &mut mi_s);
        absorb_dense_range_tiled(&g, &mut ag_t, &mut mi_t);
        assert_eq!(bits_of(&ag_s), bits_of(&ag_t), "dense agg p={p}");
        assert_eq!(bits_of(&mi_s), bits_of(&mi_t), "dense mir p={p}");

        let codes: Vec<u32> = (0..p).map(|i| ((i * 7) % 8) as u32).collect();
        let (mut ag_s, mut mi_s) = (agg0.clone(), mir0.clone());
        let (mut ag_t, mut mi_t) = (agg0.clone(), mir0.clone());
        absorb_innovation_range_scalar(&codes, 1.25, 0.3125, &mut ag_s, &mut mi_s);
        absorb_innovation_range_tiled(&codes, 1.25, 0.3125, &mut ag_t, &mut mi_t);
        assert_eq!(bits_of(&ag_s), bits_of(&ag_t), "innovation agg p={p}");
        assert_eq!(bits_of(&mi_s), bits_of(&mi_t), "innovation mir p={p}");

        let mut ag_s = agg0.clone();
        let mut ag_t = agg0;
        absorb_fresh_range_scalar(&g, &mut ag_s);
        absorb_fresh_range_tiled(&g, &mut ag_t);
        assert_eq!(bits_of(&ag_s), bits_of(&ag_t), "fresh agg p={p}");
    }
}

#[test]
fn pack_unpack_twins_byte_exact_over_widths_and_offsets() {
    for bits in 1..=16u32 {
        let mask = (1u64 << bits) - 1;
        for &p in &[0usize, 1, 7, 8, 9, 64, 203] {
            let codes: Vec<u32> =
                (0..p).map(|i| ((i as u64).wrapping_mul(0x2545F491) & mask) as u32).collect();
            for pre in [0u32, 1, 3, 7] {
                let mut ws = BitWriter::new();
                let mut wt = BitWriter::new();
                if pre > 0 {
                    ws.write(0x2D & ((1 << pre) - 1), pre);
                    wt.write(0x2D & ((1 << pre) - 1), pre);
                }
                pack_codes_scalar(&codes, bits, &mut ws);
                pack_codes_tiled(&codes, bits, &mut wt);
                assert_eq!(
                    ws.as_bytes(),
                    wt.as_bytes(),
                    "pack drift bits={bits} p={p} pre={pre}"
                );
                assert_eq!(ws.len_bits(), wt.len_bits());

                let bytes = ws.into_bytes();
                let mut rs = BitReader::new(&bytes);
                let mut rt = BitReader::new(&bytes);
                if pre > 0 {
                    rs.read(pre).unwrap();
                    rt.read(pre).unwrap();
                }
                let mut out_s = Vec::new();
                let mut out_t = Vec::new();
                unpack_codes_into_scalar(&mut rs, bits, p, &mut out_s).unwrap();
                unpack_codes_into_tiled(&mut rt, bits, p, &mut out_t).unwrap();
                assert_eq!(out_s, codes, "scalar unpack bits={bits} p={p} pre={pre}");
                assert_eq!(out_t, codes, "tiled unpack bits={bits} p={p} pre={pre}");
            }
        }
    }
}

// --- trainer-level sweep --------------------------------------------------

/// Serializes tests that flip the process-wide kernel mode.
static KERNEL_LOCK: Mutex<()> = Mutex::new(());

fn cfg_for(algo: Algo, kernels: KernelMode, threads: usize, shards: usize) -> RunCfg {
    // EXACTLY wire_equivalence.rs's cfg_for shape, so the sync traces
    // here hash to the same fingerprints as the recorded goldens
    let mut c = RunCfg::paper_logreg(algo);
    c.data.n_train = 240;
    c.data.n_test = 60;
    c.workers = 4;
    c.iters = 30;
    c.batch = 40;
    c.record_every = 1;
    c.threads = threads;
    c.server_shards = shards;
    c.wire_mode = WireMode::Sync;
    c.staleness_bound = 0;
    c.downlink = laq::config::DownlinkMode::Exact;
    if algo.is_stochastic() {
        c.alpha = 0.01;
    }
    c.kernels = kernels;
    c
}

#[derive(Debug, PartialEq)]
struct Trace {
    steps: Vec<(f64, f64, u64, usize, f64)>,
    rounds: u64,
    bits: u64,
    sim_time: f64,
    per_worker_rounds: Vec<u64>,
    clocks: Vec<usize>,
    theta: Vec<f32>,
}

fn run_trace(cfg: &RunCfg) -> Trace {
    let mut t = laq::algo::build_native(cfg).unwrap();
    let mut steps = Vec::with_capacity(cfg.iters);
    for _ in 0..cfg.iters {
        let s = t.step().unwrap();
        steps.push((s.loss, s.grad_norm_sq, s.bits, s.uploads, s.max_eps_sq));
    }
    Trace {
        steps,
        rounds: t.net.uplink_rounds(),
        bits: t.net.uplink_bits(),
        sim_time: t.net.sim_time(),
        per_worker_rounds: t.net.per_worker_rounds().to_vec(),
        clocks: t.clocks(),
        theta: t.theta().to_vec(),
    }
}

/// The acceptance pin: kernels=tiled ≡ kernels=scalar bit-identically on
/// all nine algorithms across {1,4} threads × {1,7} shards.
#[test]
fn tiled_kernels_bit_identical_to_scalar_on_all_nine_algorithms() {
    let _g = KERNEL_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    for algo in Algo::all() {
        let scalar = run_trace(&cfg_for(algo, KernelMode::Scalar, 1, 1));
        for (threads, shards) in [(1usize, 1usize), (1, 7), (4, 1), (4, 7)] {
            let tiled = run_trace(&cfg_for(algo, KernelMode::Tiled, threads, shards));
            assert_eq!(
                scalar,
                tiled,
                "{}: kernels=tiled threads={threads} shards={shards} \
                 diverged from kernels=scalar",
                algo.name()
            );
        }
    }
    // leave the process default in place for any test that runs after us
    laq::util::kernel::set_mode(KernelMode::Tiled);
}

fn fnv1a(h: u64, v: u64) -> u64 {
    let mut h = h;
    for b in v.to_le_bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

fn fingerprint(t: &Trace) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for s in &t.steps {
        h = fnv1a(h, s.0.to_bits());
        h = fnv1a(h, s.1.to_bits());
        h = fnv1a(h, s.2);
        h = fnv1a(h, s.3 as u64);
        h = fnv1a(h, s.4.to_bits());
    }
    h = fnv1a(h, t.rounds);
    h = fnv1a(h, t.bits);
    h = fnv1a(h, t.sim_time.to_bits());
    for &r in &t.per_worker_rounds {
        h = fnv1a(h, r);
    }
    for &c in &t.clocks {
        h = fnv1a(h, c as u64);
    }
    for &x in &t.theta {
        h = fnv1a(h, x.to_bits() as u64);
    }
    h
}

/// Both kernel modes must reproduce the `sync` fingerprints recorded in
/// `golden_sync_traces.txt` (seeded by `wire_equivalence.rs`; skipped
/// silently in a fresh checkout before that file exists) — the direct
/// proof that the tiled rewrite moved no golden.
#[test]
fn both_kernel_modes_reproduce_the_recorded_sync_goldens() {
    let _g = KERNEL_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let path =
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/golden_sync_traces.txt");
    let Ok(golden) = std::fs::read_to_string(&path) else {
        // not seeded yet: wire_equivalence's own run will create it, and
        // the CI legs re-run this suite with the file present
        return;
    };
    for algo in Algo::all() {
        let want = golden
            .lines()
            .find_map(|l| l.strip_prefix(&format!("sync {} ", algo.name())).map(str::to_string));
        let Some(want) = want else { continue };
        for mode in [KernelMode::Scalar, KernelMode::Tiled] {
            let t = run_trace(&cfg_for(algo, mode, 1, 1));
            let got = format!("{:016x}", fingerprint(&t));
            assert_eq!(
                got,
                want,
                "{} under kernels={} no longer matches the recorded sync golden",
                algo.name(),
                mode.name()
            );
        }
    }
    laq::util::kernel::set_mode(KernelMode::Tiled);
}
