//! PJRT runtime tests over the real AOT artifacts (skipped with a notice
//! if `make artifacts` has not run).  These are the cross-layer
//! correctness proofs:
//!   * rust native gradients == artifact gradients (L2/L3 agreement);
//!   * rust innovation codec == Pallas quantization kernel (L1/L3
//!     agreement, bit-exact on the integer codes).

use laq::data::Dataset;
use laq::model::logreg::LogRegWorker;
use laq::model::{LossCfg, WorkerGrad};
use laq::quant::InnovationQuantizer;
use laq::runtime::{PjrtGradWorker, Runtime, Value};
use laq::util::rng::Rng;
use std::sync::Arc;

fn runtime() -> Option<Arc<Runtime>> {
    match Runtime::open("artifacts") {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("SKIP runtime tests: {e}");
            None
        }
    }
}

fn tiny_shard(seed: u64, n: usize, f: usize, c: usize) -> Dataset {
    let mut rng = Rng::new(seed);
    let x: Vec<f32> = (0..n * f).map(|_| rng.normal() as f32).collect();
    let y: Vec<u32> = (0..n).map(|_| rng.below(c as u64) as u32).collect();
    Dataset { n, features: f, classes: c, x: x.into(), y: y.into() }
}

#[test]
fn manifest_lists_expected_artifacts() {
    let Some(rt) = runtime() else { return };
    let names = rt.artifact_names();
    for want in [
        "logreg_grad",
        "logreg_grad_batch",
        "logreg_grad_tiny",
        "logreg_predict",
        "mlp_grad",
        "mlp_predict",
        "quantize_b3",
        "quantize_tiny",
        "tfm_grad",
    ] {
        assert!(names.contains(&want), "missing artifact {want}");
    }
}

#[test]
fn pjrt_logreg_grad_matches_native() {
    let Some(rt) = runtime() else { return };
    // logreg_grad_tiny: shard 64 × 32, 4 classes, N_global 256, M 4
    let shard = tiny_shard(3, 64, 32, 4);
    let cfg = LossCfg { n_global: 256, l2: 0.01, n_workers: 4 };
    let mut native = LogRegWorker::new(shard.clone(), cfg);
    let mut pjrt = PjrtGradWorker::new(Arc::clone(&rt), "logreg_grad_tiny", None, shard).unwrap();

    let mut rng = Rng::new(9);
    for trial in 0..3 {
        let theta: Vec<f32> = (0..128).map(|_| (rng.normal() * 0.3) as f32).collect();
        let (l_n, g_n) = native.full(&theta).unwrap();
        let (l_p, g_p) = pjrt.full(&theta).unwrap();
        assert!(
            (l_n - l_p).abs() < 1e-5 * l_n.abs().max(1.0),
            "trial {trial}: loss {l_n} vs {l_p}"
        );
        for (i, (a, b)) in g_n.iter().zip(&g_p).enumerate() {
            assert!(
                (a - b).abs() < 1e-4 * (1.0 + a.abs()),
                "trial {trial} grad[{i}]: {a} vs {b}"
            );
        }
    }
}

#[test]
fn rust_codec_matches_pallas_kernel_bit_exactly() {
    let Some(rt) = runtime() else { return };
    // quantize_tiny: p = 128, b = 3
    let q = InnovationQuantizer::new(3);
    let mut rng = Rng::new(11);
    for trial in 0..5 {
        let g: Vec<f32> = (0..128).map(|_| rng.normal() as f32).collect();
        let qp: Vec<f32> = (0..128).map(|_| rng.normal() as f32).collect();
        let (r_pal, codes_pal, deq_pal) =
            rt.quantize_via_artifact("quantize_tiny", &g, &qp).unwrap();
        let (qi, q_new) = q.quantize(&g, &qp);
        assert_eq!(qi.radius, r_pal, "trial {trial}: radius");
        assert_eq!(qi.codes, codes_pal, "trial {trial}: integer codes");
        for (i, (a, b)) in q_new.iter().zip(&deq_pal).enumerate() {
            assert!(
                (a - b).abs() <= 4e-6,
                "trial {trial} deq[{i}]: {a} vs {b}"
            );
        }
    }
}

#[test]
fn call_rejects_bad_shapes_and_dtypes() {
    let Some(rt) = runtime() else { return };
    // wrong arity
    assert!(rt.call("quantize_tiny", &[Value::F32(vec![0.0; 128])]).is_err());
    // wrong length
    assert!(rt
        .call(
            "quantize_tiny",
            &[Value::F32(vec![0.0; 127]), Value::F32(vec![0.0; 128])]
        )
        .is_err());
    // wrong dtype
    assert!(rt
        .call(
            "quantize_tiny",
            &[Value::I32(vec![0; 128]), Value::F32(vec![0.0; 128])]
        )
        .is_err());
    // unknown artifact
    assert!(rt.call("nope", &[]).is_err());
}

#[test]
fn quantize_b3_full_dim_matches_rust_codec() {
    let Some(rt) = runtime() else { return };
    // the full 7 840-dim artifact used by the logreg LAQ path
    let p = rt.signature("quantize_b3").unwrap().inputs[0].elements();
    let q = InnovationQuantizer::new(3);
    let mut rng = Rng::new(13);
    let g: Vec<f32> = (0..p).map(|_| rng.normal() as f32).collect();
    let qp = vec![0.0f32; p];
    let (r_pal, codes_pal, _) = rt.quantize_via_artifact("quantize_b3", &g, &qp).unwrap();
    let (qi, _) = q.quantize(&g, &qp);
    assert_eq!(qi.radius, r_pal);
    assert_eq!(qi.codes, codes_pal);
}
