//! Convergence-contract harness for the cross-round staleness wire mode
//! (`wire_mode = async-cross`).
//!
//! Cross-round staleness deliberately **changes algorithm semantics** —
//! round-k uploads may be absorbed while round k+1..k+bound local phases
//! are already running — so there is no bit-exact sync oracle to compare
//! against.  This harness replaces bit-identity with the checkable
//! contracts that make the mode trustworthy, under a seeded adversarial
//! latency schedule (the latency model's jitter stream draws a round lag
//! for *every* (worker, round); roughly `bound/(bound+1)` of all uploads
//! cross a round boundary):
//!
//! * **(a) bounded staleness** — no absorbed upload ever lags more than
//!   `staleness_bound` rounds behind its origin, and the schedule
//!   actually defers uploads (it is not vacuously sync);
//! * **(b) determinism** — a cross-round trace is a pure function of
//!   (seed, config): identical across repeated runs and across every
//!   (threads, shards) combination, for all nine algorithms;
//! * **(c) accounting exactness** — bits/rounds/latency-clock fold at the
//!   *origin* round in worker index order, so for algorithms whose
//!   message sequence is trajectory-independent they are **bit-equal to
//!   sync** even at staleness 3 (QGD/GD force an upload per round;
//!   SGD/QSGD/EF-SGD upload unconditionally with content-independent
//!   sizes; SSGD's sizes are content-dependent, so only its rounds pin);
//! * **(d) convergence tolerance** — on strongly convex logistic
//!   regression the loss trajectory still contracts, and its endpoint
//!   stays within a staleness-dependent tolerance of the sync endpoint
//!   (the lazy recursion tolerates outdated gradients — A-LAQ / LASG);
//! * **(e) exact degeneration** — `staleness_bound = 0` is bit-identical
//!   to sync for all nine algorithms, at any (threads, shards);
//! * **(f) mirror re-synchronization** — while an upload is in flight the
//!   server's mirror legitimately lags the worker's; once the worker has
//!   nothing in flight the two are bit-equal again, and the aggregate
//!   identity `∇ = Σ_m mirror_m` holds throughout;
//! * **(g) mid-flight resume** — a v3 checkpoint taken with uploads in
//!   flight replays the remaining trace bit-for-bit.

use laq::config::{Algo, RunCfg, WireMode};

fn cfg_for(
    algo: Algo,
    wire: WireMode,
    staleness: usize,
    threads: usize,
    shards: usize,
) -> RunCfg {
    let mut c = RunCfg::paper_logreg(algo);
    // mnist-like keeps p = 7840 (8 coordinate blocks ⇒ real shard plans);
    // tiny row counts keep the suite fast
    c.data.n_train = 240;
    c.data.n_test = 60;
    c.workers = 4;
    c.iters = 30;
    c.batch = 40;
    c.record_every = 1;
    c.threads = threads;
    c.server_shards = shards;
    c.wire_mode = wire;
    c.staleness_bound = staleness;
    if algo.is_stochastic() {
        c.alpha = 0.01;
    }
    c
}

/// Everything observable about a run, collected per iteration.
#[derive(Debug, PartialEq)]
struct Trace {
    // (loss, grad_norm_sq, bits, uploads, max_eps_sq) per step — f64
    // compared exactly where a contract is bit-for-bit
    steps: Vec<(f64, f64, u64, usize, f64)>,
    rounds: u64,
    bits: u64,
    sim_time: f64,
    per_worker_rounds: Vec<u64>,
    clocks: Vec<usize>,
    theta: Vec<f32>,
    max_lag: usize,
    deferred: u64,
    in_flight_end: usize,
}

fn run_trace(cfg: &RunCfg) -> Trace {
    let mut t = laq::algo::build_native(cfg).unwrap();
    let mut steps = Vec::with_capacity(cfg.iters);
    for _ in 0..cfg.iters {
        let s = t.step().unwrap();
        steps.push((s.loss, s.grad_norm_sq, s.bits, s.uploads, s.max_eps_sq));
    }
    let (max_lag, deferred) = t.staleness_stats();
    Trace {
        steps,
        rounds: t.net.uplink_rounds(),
        bits: t.net.uplink_bits(),
        sim_time: t.net.sim_time(),
        per_worker_rounds: t.net.per_worker_rounds().to_vec(),
        clocks: t.clocks(),
        theta: t.theta().to_vec(),
        max_lag,
        deferred,
        in_flight_end: t.in_flight_uploads(),
    }
}

// ---- (a) bounded staleness ------------------------------------------------

#[test]
fn observed_staleness_never_exceeds_the_bound() {
    for algo in Algo::all() {
        for bound in [1usize, 3] {
            let t = run_trace(&cfg_for(algo, WireMode::AsyncCross, bound, 1, 1));
            assert!(
                t.max_lag <= bound,
                "{}: observed lag {} > bound {bound}",
                algo.name(),
                t.max_lag
            );
            // at most one upload per (worker, in-flight round) can be
            // parked at any time
            assert!(
                t.in_flight_end <= 4 * bound,
                "{}: {} uploads in flight at bound {bound}",
                algo.name(),
                t.in_flight_end
            );
            // the schedule must be genuinely adversarial for algorithms
            // that upload every round (the lazy ones may skip, so their
            // deferral count is trajectory-dependent)
            if !matches!(algo, Algo::Lag | Algo::Laq | Algo::Slaq) {
                assert!(
                    t.deferred > 0,
                    "{}: schedule deferred nothing at bound {bound}",
                    algo.name()
                );
                assert!(
                    t.max_lag > 0,
                    "{}: nothing ever landed late at bound {bound}",
                    algo.name()
                );
            }
        }
    }
}

// ---- (b) determinism across threads × shards ------------------------------

#[test]
fn cross_trace_is_a_pure_function_of_seed_and_config() {
    for algo in Algo::all() {
        let base = run_trace(&cfg_for(algo, WireMode::AsyncCross, 2, 1, 1));
        for (threads, shards) in [(1usize, 7usize), (4, 1), (4, 7)] {
            let t = run_trace(&cfg_for(algo, WireMode::AsyncCross, 2, threads, shards));
            assert_eq!(
                base,
                t,
                "{}: async-cross s=2 threads={threads} shards={shards} not reproducible",
                algo.name()
            );
        }
        // racing schedules across two identical runs must still agree
        let again = run_trace(&cfg_for(algo, WireMode::AsyncCross, 2, 4, 7));
        assert_eq!(base, again, "{}: async-cross rerun diverged", algo.name());
    }
}

#[test]
fn cross_trace_depends_on_the_seed() {
    // sanity against a trivially-constant implementation: a different
    // seed draws a different lag schedule and a different trajectory
    let a = run_trace(&cfg_for(Algo::Laq, WireMode::AsyncCross, 2, 1, 1));
    let mut cfg = cfg_for(Algo::Laq, WireMode::AsyncCross, 2, 1, 1);
    cfg.seed = 99;
    cfg.data.seed = 99;
    let b = run_trace(&cfg);
    assert_ne!(a.theta, b.theta);
}

// ---- (c) accounting bit-equal to sync -------------------------------------

#[test]
fn cross_accounting_is_bit_equal_to_sync() {
    // trajectory-independent message sequences: every worker uploads
    // every round with a content-independent wire size, so accounting
    // must match sync exactly even though absorption crosses rounds
    for algo in [Algo::Qgd, Algo::Gd, Algo::Sgd, Algo::Qsgd, Algo::EfSgd] {
        let sync = run_trace(&cfg_for(algo, WireMode::Sync, 0, 1, 1));
        let cross = run_trace(&cfg_for(algo, WireMode::AsyncCross, 3, 4, 7));
        assert_eq!(sync.rounds, cross.rounds, "{}", algo.name());
        assert_eq!(sync.bits, cross.bits, "{}", algo.name());
        assert_eq!(sync.per_worker_rounds, cross.per_worker_rounds, "{}", algo.name());
        assert_eq!(
            sync.sim_time.to_bits(),
            cross.sim_time.to_bits(),
            "{}: latency clock drifted",
            algo.name()
        );
        // per-step accounting folds at the origin round too
        for (i, (s, c)) in sync.steps.iter().zip(cross.steps.iter()).enumerate() {
            assert_eq!(s.2, c.2, "{}: step {i} bits", algo.name());
            assert_eq!(s.3, c.3, "{}: step {i} uploads", algo.name());
        }
    }
    // SSGD's message sizes are content-dependent (nnz), so only its
    // round counts are trajectory-independent
    let sync = run_trace(&cfg_for(Algo::Ssgd, WireMode::Sync, 0, 1, 1));
    let cross = run_trace(&cfg_for(Algo::Ssgd, WireMode::AsyncCross, 3, 4, 7));
    assert_eq!(sync.rounds, cross.rounds);
    assert_eq!(sync.per_worker_rounds, cross.per_worker_rounds);
}

// ---- (d) convergence tolerance on strongly convex logreg ------------------

#[test]
fn strongly_convex_logreg_contracts_within_staleness_tolerance() {
    // l2-regularized logistic regression is strongly convex; the lazy
    // recursion tolerates outdated gradients, so the cross trajectory
    // must still contract and end within a staleness-dependent band of
    // the sync endpoint
    for algo in [Algo::Gd, Algo::Lag, Algo::Laq] {
        let mut sync_cfg = cfg_for(algo, WireMode::Sync, 0, 1, 1);
        sync_cfg.data.name = "ijcnn1".into();
        sync_cfg.data.n_train = 400;
        sync_cfg.iters = 120;
        let sync = run_trace(&sync_cfg);
        let first = sync.steps.first().unwrap().0;
        let sync_last = sync.steps.last().unwrap().0;
        assert!(
            sync_last < 0.8 * first,
            "{}: sync did not contract ({first} -> {sync_last})",
            algo.name()
        );
        for bound in [1usize, 3] {
            let mut cfg = sync_cfg.clone();
            cfg.wire_mode = WireMode::AsyncCross;
            cfg.staleness_bound = bound;
            let cross = run_trace(&cfg);
            let last = cross.steps.last().unwrap().0;
            assert!(
                last < 0.8 * first,
                "{} bound {bound}: cross did not contract ({first} -> {last})",
                algo.name()
            );
            let tol = 0.04 * (1.0 + bound as f64);
            assert!(
                (last - sync_last).abs() <= tol * sync_last.abs().max(1e-9),
                "{} bound {bound}: final loss {last} vs sync {sync_last} \
                 outside tolerance {tol}",
                algo.name()
            );
        }
    }
}

// ---- (e) bound 0 degenerates exactly to sync ------------------------------

#[test]
fn cross_with_zero_staleness_is_bit_identical_to_sync() {
    for algo in Algo::all() {
        let sync = run_trace(&cfg_for(algo, WireMode::Sync, 0, 1, 1));
        for (threads, shards) in [(1usize, 1usize), (4, 7)] {
            let cross = run_trace(&cfg_for(algo, WireMode::AsyncCross, 0, threads, shards));
            assert_eq!(
                sync,
                cross,
                "{}: async-cross s=0 threads={threads} shards={shards} diverged from sync",
                algo.name()
            );
        }
    }
}

// ---- (f) mirror re-synchronization + aggregate identity -------------------

#[test]
fn mirrors_resync_after_landing_and_aggregate_identity_holds() {
    let cfg = cfg_for(Algo::Laq, WireMode::AsyncCross, 2, 1, 1);
    let mut t = laq::algo::build_native(&cfg).unwrap();
    let mut resynced_checks = 0usize;
    for _ in 0..cfg.iters {
        t.step().unwrap();
        // the server-side invariant ∇ == Σ mirrors holds every round,
        // in-flight uploads or not (they are absorbed atomically)
        assert!(t.aggregate_drift() < 1e-3, "aggregate identity broken");
        for m in 0..t.n_workers() {
            if !t.worker_in_flight(m) {
                assert_eq!(
                    t.worker_mirror(m),
                    t.server_mirror(m),
                    "worker {m} mirror did not re-synchronize"
                );
                resynced_checks += 1;
            }
        }
    }
    assert!(resynced_checks > 0, "no in-sync window ever observed");
    let (max_lag, deferred) = t.staleness_stats();
    assert!(deferred > 0, "LAQ never deferred an upload in 30 rounds");
    assert!(max_lag <= 2);
}

// ---- (g) mid-flight checkpoint resume -------------------------------------

#[test]
fn mid_flight_checkpoint_resume_replays_exactly() {
    let dir = std::env::temp_dir().join("laq_cross_ckpt");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("mid.ckpt");
    let cfg = cfg_for(Algo::Laq, WireMode::AsyncCross, 2, 1, 1);

    // uninterrupted reference run
    let mut straight = laq::algo::build_native(&cfg).unwrap();
    for _ in 0..24 {
        straight.step().unwrap();
    }

    // split at the first boundary (from step 6 on) where uploads are
    // genuinely in flight, so the persisted payload/deadline machinery is
    // actually exercised — a fixed split could go vacuous if the seeded
    // schedule happened to leave nothing pending there
    let mut first = laq::algo::build_native(&cfg).unwrap();
    let mut split = 0usize;
    for s in 1..=12 {
        first.step().unwrap();
        if s >= 6 && first.in_flight_uploads() > 0 {
            split = s;
            break;
        }
    }
    assert!(
        split > 0,
        "no uploads in flight at any candidate split — the schedule never \
         exercised cross-round state"
    );
    let in_flight = first.in_flight_uploads();
    first.save_checkpoint(&path).unwrap();

    // resume on a trainer configured sync — the checkpoint's recorded
    // cross-round schedule (and its in-flight payloads) must take over
    let mut resumed = laq::algo::build_native(&cfg_for(Algo::Laq, WireMode::Sync, 0, 4, 7))
        .unwrap();
    resumed.load_checkpoint(&path).unwrap();
    assert_eq!(resumed.cfg.wire_mode, WireMode::AsyncCross);
    assert_eq!(resumed.cfg.staleness_bound, 2);
    assert_eq!(resumed.in_flight_uploads(), in_flight);
    for _ in 0..(24 - split) {
        resumed.step().unwrap();
    }

    assert_eq!(straight.theta(), resumed.theta());
    let _ = std::fs::remove_dir_all(&dir);
}
