//! Contracts of the scenario engine (`cfg.scenario`): fault-injected,
//! heterogeneous, elastic fleets under the convergence contract.
//!
//! * **empty-scenario identity** — a config whose `[scenario]` table is
//!   absent or empty drives the exact pre-scenario trainer: traces are
//!   bit-identical across the (threads, shards) grid, and the
//!   `wire_equivalence` goldens (which predate the engine) stay
//!   unchanged.
//! * **purity** — every scenario trace is a pure function of
//!   (seed, config): identical across reruns and across every
//!   (threads, shards) combination, under sync and async wire modes.
//!   All fault randomness rides dedicated counter-based streams.
//! * **degeneration** — `wire_mode = async, staleness_bound = 0` with a
//!   scenario is bit-identical to sync with the same scenario (the same
//!   contract the fault-free engine honors).
//! * **graceful degradation** — a fleet with one worker dropped for 30%
//!   of the run plus one heavy-tailed straggler still contracts on
//!   strongly convex logreg, to within a scenario-dependent tolerance
//!   of the fault-free final loss; mirror retirement/repriming keeps the
//!   lazy-aggregate invariant tight throughout.
//! * **corrupt-upload rejection** — injected corrupt frames are detected
//!   at decode, billed (they crossed the wire), logged and rejected;
//!   NaN never reaches θ and the bit/round accounting stays exact.

use laq::config::{Algo, RunCfg, ScenarioCfg, WireMode, WorkerFaults};

fn cfg_for(algo: Algo, wire: WireMode, staleness: usize, threads: usize, shards: usize) -> RunCfg {
    let mut c = RunCfg::paper_logreg(algo);
    // mnist-like keeps p = 7840 (8 coordinate blocks ⇒ real shard plans);
    // tiny row counts keep the suite fast
    c.data.n_train = 240;
    c.data.n_test = 60;
    c.workers = 4;
    c.iters = 30;
    c.batch = 40;
    c.record_every = 1;
    c.threads = threads;
    c.server_shards = shards;
    c.wire_mode = wire;
    c.staleness_bound = staleness;
    c.downlink = laq::config::DownlinkMode::Exact;
    if algo.is_stochastic() {
        c.alpha = 0.01;
    }
    c
}

/// The reference fault fleet: worker 0 corrupt-prone, worker 1 a
/// heavy-tailed straggler with a finite deadline, worker 3 dropped for
/// the middle 30% of a 30-round run.
fn fault_fleet() -> ScenarioCfg {
    let mut s = ScenarioCfg::default();
    s.workers = vec![
        WorkerFaults { worker: 0, corrupt_rate: 0.3, ..WorkerFaults::default() },
        WorkerFaults {
            worker: 1,
            straggle_alpha: Some(1.2),
            deadline: 4.0,
            ..WorkerFaults::default()
        },
        WorkerFaults {
            worker: 3,
            drop_from: Some(9),
            drop_until: Some(18),
            ..WorkerFaults::default()
        },
    ];
    s
}

/// Everything observable about a run, collected per iteration and
/// compared exactly — the contracts here are bit-for-bit unless a test
/// says otherwise.
#[derive(Debug, PartialEq)]
struct Trace {
    steps: Vec<(f64, f64, u64, usize, f64)>,
    rounds: u64,
    bits: u64,
    down_bits: u64,
    sim_time: f64,
    per_worker_rounds: Vec<u64>,
    clocks: Vec<usize>,
    rejections: u64,
    theta: Vec<f32>,
}

fn run_trace(cfg: &RunCfg) -> Trace {
    let mut t = laq::algo::build_native(cfg).unwrap();
    let mut steps = Vec::with_capacity(cfg.iters);
    for _ in 0..cfg.iters {
        let s = t.step().unwrap();
        steps.push((s.loss, s.grad_norm_sq, s.bits, s.uploads, s.max_eps_sq));
    }
    Trace {
        steps,
        rounds: t.net.uplink_rounds(),
        bits: t.net.uplink_bits(),
        down_bits: t.net.downlink_bits(),
        sim_time: t.net.sim_time(),
        per_worker_rounds: t.net.per_worker_rounds().to_vec(),
        clocks: t.clocks(),
        rejections: t.scenario_rejections(),
        theta: t.theta().to_vec(),
    }
}

#[test]
fn empty_scenario_is_bit_identical_across_the_grid() {
    // acceptance (a): the empty scenario drives the pre-scenario trainer
    // bit-for-bit at every (threads, shards) ∈ {1,4} × {1,7} — and a
    // TOML config with a present-but-empty [scenario] table parses to
    // the same empty scenario
    let toml = "[scenario]\n";
    for algo in [Algo::Laq, Algo::Slaq] {
        let base = run_trace(&cfg_for(algo, WireMode::Sync, 0, 1, 1));
        for (threads, shards) in [(1usize, 7usize), (4, 1), (4, 7)] {
            let mut cfg = cfg_for(algo, WireMode::Sync, 0, threads, shards);
            let j = laq::config::toml::parse(toml).unwrap();
            cfg.apply_json(&j).unwrap();
            assert!(cfg.scenario.is_empty(), "an empty [scenario] table must stay empty");
            let t = run_trace(&cfg);
            assert_eq!(
                base,
                t,
                "{}: empty scenario threads={threads} shards={shards} diverged",
                algo.name()
            );
        }
    }
}

#[test]
fn scenario_trace_is_a_pure_function_of_seed_and_config() {
    // acceptance (b): the full fault fleet — corrupt + straggler +
    // dropout — reproduces bit-for-bit across reruns and across the
    // thread/shard grid, under sync and pipelined-async wire phases
    for (wire, staleness) in [(WireMode::Sync, 0usize), (WireMode::Async, 2)] {
        let mut base_cfg = cfg_for(Algo::Laq, wire, staleness, 1, 1);
        base_cfg.scenario = fault_fleet();
        let base = run_trace(&base_cfg);
        assert!(base.rounds > 0, "the faulted fleet must still communicate");
        for (threads, shards) in [(1usize, 7usize), (4, 1), (4, 7)] {
            let mut cfg = cfg_for(Algo::Laq, wire, staleness, threads, shards);
            cfg.scenario = fault_fleet();
            let t = run_trace(&cfg);
            assert_eq!(
                base, t,
                "scenario {wire:?} s={staleness} threads={threads} shards={shards} not reproducible"
            );
        }
        // racing schedules across two identical runs must still agree
        let mut cfg = cfg_for(Algo::Laq, wire, staleness, 4, 7);
        cfg.scenario = fault_fleet();
        let again = run_trace(&cfg);
        assert_eq!(base, again, "scenario {wire:?} rerun diverged");
    }
}

#[test]
fn async_zero_staleness_scenario_degenerates_to_sync() {
    // the scenario paths keep the fault-free engine's degeneration
    // contract: at staleness 0 the async machinery — including the
    // worker-side corrupt rejection and the phase-4 billing — is
    // bit-identical to the sync wire loop's inline handling
    let mut s_cfg = cfg_for(Algo::Laq, WireMode::Sync, 0, 1, 1);
    s_cfg.scenario = fault_fleet();
    let sync = run_trace(&s_cfg);
    for (threads, shards) in [(1usize, 1usize), (4, 7)] {
        let mut a_cfg = cfg_for(Algo::Laq, WireMode::Async, 0, threads, shards);
        a_cfg.scenario = fault_fleet();
        let asy = run_trace(&a_cfg);
        assert_eq!(
            sync, asy,
            "async s=0 threads={threads} shards={shards} diverged from sync under the scenario"
        );
    }
}

#[test]
fn faulted_fleet_still_contracts_on_strongly_convex_logreg() {
    // acceptance (c): one worker dropped for 30% of rounds + one
    // heavy-tailed straggler → the strongly convex logreg objective
    // still contracts, and lands within a scenario-dependent tolerance
    // of the fault-free final loss.  Losses compare via eval_full (all
    // workers, no scenario involvement) because the per-step trace loss
    // legitimately excludes dropped workers' shards.
    let mut free_cfg = cfg_for(Algo::Laq, WireMode::Sync, 0, 1, 1);
    free_cfg.iters = 60;
    let mut faulted_cfg = free_cfg.clone();
    faulted_cfg.scenario.workers = vec![
        WorkerFaults {
            worker: 1,
            straggle_alpha: Some(1.2),
            deadline: 5.0,
            ..WorkerFaults::default()
        },
        WorkerFaults {
            worker: 3,
            drop_from: Some(18),
            drop_until: Some(36),
            ..WorkerFaults::default()
        },
    ];

    let mut free = laq::algo::build_native(&free_cfg).unwrap();
    let mut faulted = laq::algo::build_native(&faulted_cfg).unwrap();
    let (first, _) = faulted.eval_full().unwrap();
    for _ in 0..free_cfg.iters {
        free.step().unwrap();
        faulted.step().unwrap();
    }
    let (last_free, _) = free.eval_full().unwrap();
    let (last, _) = faulted.eval_full().unwrap();

    assert!(
        last < 0.9 * first,
        "faulted fleet failed to contract: {first} -> {last}"
    );
    assert!(
        (last - last_free).abs() <= 0.25 * last_free.abs().max(1e-9),
        "faulted final loss {last} too far from fault-free {last_free}"
    );
    // mirror lifecycle: retirement + rejoin never wedged the lazy
    // aggregate — the Σ-mirrors invariant holds to float accumulation
    assert!(
        faulted.aggregate_drift() < 1e-2,
        "lazy aggregate drifted from Σ mirrors: {}",
        faulted.aggregate_drift()
    );
}

#[test]
fn corrupt_uploads_are_rejected_billed_and_never_poison_theta() {
    // acceptance (d): QGD forces an upload from every worker every
    // round, so with corrupt_rate = 0.5 on worker 0 roughly half its
    // frames are damaged in flight.  Every damaged frame must be
    // detected at decode and rejected — θ stays finite — while the
    // accounting stays exact: a rejected frame is billed like a landed
    // one (it crossed the wire), so rounds and bits match the fault-free
    // totals of the forced-upload schedule to the bit.
    let mut cfg = cfg_for(Algo::Qgd, WireMode::Sync, 0, 1, 1);
    cfg.iters = 25;
    cfg.scenario.workers =
        vec![WorkerFaults { worker: 0, corrupt_rate: 0.5, ..WorkerFaults::default() }];

    let mut t = laq::algo::build_native(&cfg).unwrap();
    for _ in 0..cfg.iters {
        t.step().unwrap();
        assert!(
            t.theta().iter().all(|x| x.is_finite()),
            "a corrupt upload poisoned θ at round {}",
            t.scenario_rejections()
        );
    }
    let rejections = t.scenario_rejections();
    assert!(rejections > 0, "corrupt_rate = 0.5 over 25 forced rounds drew no corruption");
    assert!(
        rejections < cfg.iters as u64,
        "every round rejected — the rate gate is broken"
    );

    // exact accounting: forced uploads ⇒ iters × workers billed rounds,
    // each a fixed-layout innovation frame of 32 + b·p bits
    let rounds = t.net.uplink_rounds();
    assert_eq!(rounds, (cfg.iters * cfg.workers) as u64);
    assert_eq!(t.net.per_worker_rounds()[0], cfg.iters as u64);
    let frame_bits = 32 + (cfg.bits as u64) * (t.dim() as u64);
    assert_eq!(t.net.uplink_bits(), rounds * frame_bits);
}

#[test]
fn membership_accounting_is_exact_through_leave_and_rejoin() {
    // elastic membership: the dropped worker holds no wire seat during
    // its outage (its silence clock freezes; QGD's forced schedule makes
    // the expected round counts exact), and the rejoin is billed as
    // exactly one extra exact priming broadcast on the downlink.
    let mut cfg = cfg_for(Algo::Qgd, WireMode::Sync, 0, 1, 1);
    cfg.iters = 20;
    cfg.scenario.workers = vec![WorkerFaults {
        worker: 2,
        drop_from: Some(5),
        drop_until: Some(12),
        ..WorkerFaults::default()
    }];
    let t = run_trace(&cfg);

    // worker 2 misses exactly rounds 5..12 of its forced uploads
    let expect: Vec<u64> = (0..4u64).map(|m| if m == 2 { 20 - 7 } else { 20 }).collect();
    assert_eq!(t.per_worker_rounds, expect);
    // downlink: 20 per-round broadcasts + 1 rejoin priming message, all
    // exact dense θ frames
    let dense = laq::comm::Network::downlink_dense_bits(7840) as u64;
    assert_eq!(t.down_bits, 21 * dense);
    assert_eq!(t.rejections, 0);
}
