//! Nonconvex workload: the paper's one-hidden-layer ReLU network under
//! LAQ vs GD vs QGD (Figure 5 / Table 2 "neural network" rows).
//!
//!     cargo run --release --example nn_training -- [hidden] [iters]
//!
//! Uses the native backend (hand-written backprop, finite-difference
//! checked against jax in the test suite).

use laq::algo::build_native;
use laq::config::{Algo, RunCfg};

fn main() -> laq::Result<()> {
    laq::util::logging::init();
    let args: Vec<String> = std::env::args().skip(1).collect();
    let hidden: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(64);
    let iters: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(150);

    println!("MLP 784-{hidden}-10, b = 8 bits, {iters} iterations, M = 10 workers\n");
    let mut results = Vec::new();
    for algo in [Algo::Gd, Algo::Qgd, Algo::Laq] {
        let mut cfg = RunCfg::paper_mlp(algo);
        cfg.hidden = hidden;
        cfg.iters = iters;
        cfg.data.n_train = 2_000;
        cfg.data.n_test = 500;
        cfg.record_every = 5;
        let mut trainer = build_native(&cfg)?;
        let res = trainer.run()?;
        let g0 = res.trace.first().map(|t| t.grad_norm_sq).unwrap_or(f64::NAN);
        let g1 = res.trace.last().map(|t| t.grad_norm_sq).unwrap_or(f64::NAN);
        println!(
            "{:<4} | ||grad||² {:.3e} -> {:.3e} | acc {:.4} | rounds {:>6} | bits {:>13}",
            res.algo,
            g0,
            g1,
            res.final_accuracy.unwrap_or(0.0),
            res.total_rounds,
            res.total_bits,
        );
        res.write_to(std::path::Path::new("results/example_nn"), &res.algo.to_lowercase())?;
        results.push(res);
    }
    let (gd, laq) = (&results[0], &results[2]);
    println!(
        "\nLAQ transmitted {:.0}× fewer bits than GD on the nonconvex model.",
        gd.total_bits as f64 / laq.total_bits.max(1) as f64
    );
    Ok(())
}
