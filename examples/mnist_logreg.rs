//! MNIST-like logistic regression under all four gradient-based methods
//! (Figure 4 / Table 2 workload), on either backend:
//!
//!     cargo run --release --example mnist_logreg -- [native|pjrt] [iters]
//!
//! `pjrt` runs every worker's gradient through the AOT HLO artifact
//! (L2 jax graph + L1 Pallas kernels, compiled once at startup) — build
//! them first with `make artifacts`.  Shapes are fixed by the artifacts:
//! 10 000 train / 2 000 test, M = 10.

use laq::algo::{build_native, build_pjrt};
use laq::config::{Algo, Backend, RunCfg};
use laq::runtime::Runtime;

fn main() -> laq::Result<()> {
    laq::util::logging::init();
    let args: Vec<String> = std::env::args().skip(1).collect();
    let backend = match args.first().map(|s| s.as_str()) {
        Some("pjrt") => Backend::Pjrt,
        _ => Backend::Native,
    };
    let iters: usize = args
        .get(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(if backend == Backend::Pjrt { 60 } else { 400 });

    let rt = if backend == Backend::Pjrt {
        let rt = Runtime::open("artifacts")?;
        rt.warmup(&["logreg_grad"])?;
        Some(rt)
    } else {
        None
    };

    println!("backend: {backend:?}, iters: {iters}\n");
    let mut results = Vec::new();
    for algo in [Algo::Gd, Algo::Qgd, Algo::Lag, Algo::Laq] {
        let mut cfg = RunCfg::paper_logreg(algo);
        cfg.backend = backend;
        cfg.iters = iters;
        if backend == Backend::Native {
            cfg.data.n_train = 4_000;
            cfg.data.n_test = 1_000;
        }
        let mut trainer = match &rt {
            Some(rt) => build_pjrt(&cfg, std::sync::Arc::clone(rt)),
            None => build_native(&cfg),
        }?;
        let res = trainer.run()?;
        println!(
            "{:<4} | loss {:.5} | acc {:.4} | rounds {:>6} | bits {:>13} | sim {:.2}s",
            res.algo,
            res.final_loss(),
            res.final_accuracy.unwrap_or(0.0),
            res.total_rounds,
            res.total_bits,
            res.sim_time,
        );
        res.write_to(std::path::Path::new("results/example_mnist"), &res.algo.to_lowercase())?;
        results.push(res);
    }
    println!("\ntraces written to results/example_mnist/*.csv");
    println!(
        "expected ordering (paper Fig. 4): bits LAQ < LAG < QGD < GD; rounds LAG ~ LAQ << QGD = GD"
    );
    Ok(())
}
