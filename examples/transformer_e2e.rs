//! End-to-end driver: distributed training of a transformer language
//! model with LAQ, through the FULL three-layer stack —
//!
//!   L1/L2  python/compile (Pallas kernels + jax transformer fwd/bwd)
//!          → AOT-lowered to artifacts/tfm_grad.hlo.txt by `make artifacts`
//!   L3     this binary: rust coordinator executes the artifact via PJRT
//!          for every worker, applies the LAQ selection criterion (7),
//!          quantizes innovations, and updates parameters — no python
//!          anywhere in the process.
//!
//!     make artifacts && cargo run --release --example transformer_e2e -- [iters] [algo]
//!
//! Workload: a synthetic Markov-chain corpus (vocab 256, 4 successors per
//! token → per-token entropy log 4 ≈ 1.39 nats).  The LM (2 layers,
//! d = 128, ~0.5 M params) starts at ≈ log 256 ≈ 5.55 nats and learns the
//! bigram structure; the loss curve is recorded in
//! results/transformer_e2e/ and EXPERIMENTS.md.

use laq::algo::{lazy_codec_for, Trainer};
use laq::comm::LatencyModel;
use laq::config::{Algo, Backend, ModelKind, RunCfg};
use laq::coordinator::worker::{LazyCodec, WorkerNode};
use laq::model::WorkerGrad;
use laq::runtime::{worker::PjrtTfmWorker, Runtime};
use laq::util::rng::Rng;

/// Shared Markov transition structure: 4 deterministic successor tokens
/// per vocab entry, chosen uniformly at generation time.
fn make_corpus(vocab: usize, seq_len: usize, n_seqs: usize, seed: u64) -> Vec<Vec<i32>> {
    let mut rng = Rng::new(seed);
    let succ: Vec<[i32; 4]> = (0..vocab)
        .map(|_| {
            [
                rng.below(vocab as u64) as i32,
                rng.below(vocab as u64) as i32,
                rng.below(vocab as u64) as i32,
                rng.below(vocab as u64) as i32,
            ]
        })
        .collect();
    (0..n_seqs)
        .map(|_| {
            let mut s = Vec::with_capacity(seq_len);
            let mut cur = rng.below(vocab as u64) as i32;
            s.push(cur);
            for _ in 1..seq_len {
                cur = succ[cur as usize][rng.below(4) as usize];
                s.push(cur);
            }
            s
        })
        .collect()
}

fn main() -> laq::Result<()> {
    laq::util::logging::init();
    let args: Vec<String> = std::env::args().skip(1).collect();
    let iters: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(200);
    let algo = match args.get(1).map(|s| s.as_str()) {
        Some(a) => Algo::parse(a)?,
        None => Algo::Laq,
    };
    let alpha: f64 = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(0.01);

    let rt = Runtime::open("artifacts")?;
    let sig = rt.signature("tfm_grad")?.clone();
    let dim = sig.inputs[0].elements();
    let (batch, seq_len) = (sig.inputs[1].shape[0], sig.inputs[1].shape[1]);
    let vocab = sig.meta.get("vocab").as_usize().unwrap_or(256);
    let n_workers = sig.meta.get("n_workers").as_usize().unwrap_or(4);
    println!(
        "transformer: {dim} params, {n_workers} workers × {batch} seqs × {seq_len} tokens, algo {}",
        algo.name()
    );
    rt.warmup(&["tfm_grad"])?;

    // per-worker fixed sequence sets from the shared Markov source
    let nodes: Vec<WorkerNode<dyn WorkerGrad>> = (0..n_workers)
        .map(|m| {
            let pool = make_corpus(vocab, seq_len, batch, 42 + m as u64);
            let w: Box<dyn WorkerGrad> = Box::new(
                PjrtTfmWorker::new(std::sync::Arc::clone(&rt), "tfm_grad", pool)
                    .expect("tfm worker"),
            );
            WorkerNode::new(
                w,
                8,
                lazy_codec_for(algo).unwrap_or(LazyCodec::Quantized),
            )
        })
        .collect();

    let mut cfg = RunCfg::paper_logreg(algo);
    cfg.model = ModelKind::Transformer;
    cfg.backend = Backend::Pjrt;
    cfg.workers = n_workers;
    cfg.iters = iters;
    // server-side Adam over the lazily aggregated (quantized) gradient —
    // plain GD is impractical on transformer losses; the communication
    // machinery (criterion, codec, mirrors) is untouched by this choice
    cfg.alpha = alpha;
    cfg.bits = 8;
    cfg.l2 = 1e-4;
    cfg.record_every = 1;
    cfg.batch = n_workers * batch;
    // under server-side Adam the movement-history rhs misestimates
    // ||∇f||²; use the optimizer-agnostic grad-norm rule (13) instead
    cfg.criterion.mode = laq::config::CritMode::GradNorm;
    cfg.criterion.t_max = 25; // keep mirrors reasonably fresh for Adam

    let mut theta0 = vec![0.0f32; dim];
    Rng::new(7).fill_normal_f32(&mut theta0, 0.02);

    let mut trainer =
        Trainer::assemble(cfg, nodes, theta0, None, LatencyModel::default())?;
    trainer.set_server_opt(laq::coordinator::server::ServerOpt::adam());

    let t0 = std::time::Instant::now();
    let res = trainer.run()?;
    let wall = t0.elapsed();

    let first = res.trace.first().unwrap().loss;
    let last = res.final_loss();
    println!("\nloss curve (every {} iters):", (iters / 10).max(1));
    for t in res.trace.iter().step_by((iters / 10).max(1)) {
        println!("  iter {:>4}  loss {:.4}  rounds {:>5}  bits {:>12}", t.iter, t.loss, t.rounds, t.bits);
    }
    println!(
        "\n{}: loss {first:.4} -> {last:.4} in {wall:.1?}  (init ≈ log V = {:.3}; \
         fresh-data floor ≈ log 4 = 1.386, below it = memorizing the fixed corpus)",
        res.algo,
        (vocab as f64).ln()
    );
    println!(
        "uploads {} / {} possible ({:.1}% skipped), bits {:.3e}",
        res.total_rounds,
        (iters * n_workers) as u64,
        100.0 * (1.0 - res.total_rounds as f64 / (iters * n_workers) as f64),
        res.total_bits as f64,
    );
    res.write_to(std::path::Path::new("results/transformer_e2e"), &res.algo.to_lowercase())?;
    println!("trace: results/transformer_e2e/{}.csv", res.algo.to_lowercase());

    if last >= first * 0.7 {
        return Err(laq::Error::msg(format!(
            "loss did not drop enough: {first} -> {last}"
        )));
    }
    println!("\ne2e OK: all three layers composed (Pallas/jax AOT -> PJRT -> rust LAQ coordinator)");
    Ok(())
}
