//! Quickstart: train a distributed logistic regression with LAQ and see
//! the communication savings vs plain distributed GD.
//!
//!     cargo run --release --example quickstart
//!
//! This uses the native backend (no artifacts needed).  For the AOT
//! PJRT path, see `examples/mnist_logreg.rs --backend pjrt`.

use laq::algo::build_native;
use laq::config::{Algo, RunCfg};

fn main() -> laq::Result<()> {
    laq::util::logging::init();

    // a small mnist-like problem: 2 000 samples × 784 features, 10 classes,
    // sharded over 10 workers; paper hyperparameters otherwise
    let make = |algo| {
        let mut cfg = RunCfg::paper_logreg(algo);
        cfg.data.n_train = 2_000;
        cfg.data.n_test = 500;
        cfg.iters = 150;
        cfg
    };

    println!("training 150 iterations of distributed logistic regression...\n");
    let mut rows = Vec::new();
    for algo in [Algo::Gd, Algo::Laq] {
        let cfg = make(algo);
        let mut trainer = build_native(&cfg)?;
        let res = trainer.run()?;
        println!(
            "{:<4} | final loss {:.4} | accuracy {:.3} | uploads {:>5} | bits {:>12} | sim time {:.3}s",
            res.algo,
            res.final_loss(),
            res.final_accuracy.unwrap_or(0.0),
            res.total_rounds,
            res.total_bits,
            res.sim_time,
        );
        rows.push(res);
    }
    let (gd, laq) = (&rows[0], &rows[1]);
    println!(
        "\nLAQ used {:.1}× fewer uploads and {:.0}× fewer bits than GD at matched accuracy.",
        gd.total_rounds as f64 / laq.total_rounds as f64,
        gd.total_bits as f64 / laq.total_bits as f64,
    );
    println!("(paper: ~45× fewer uploads, ~360× fewer bits on MNIST logistic regression)");
    Ok(())
}
