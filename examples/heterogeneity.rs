//! Heterogeneity study (Proposition 1): workers with smoother local
//! losses upload less often under LAQ's selection rule.
//!
//!     cargo run --release --example heterogeneity -- [iters]
//!
//! Worker m's shard features are scaled by s_m, spanning ~an order of
//! magnitude in local smoothness L_m; the example prints the per-worker
//! upload counts alongside the L_m proxy and their rank correlation.

use laq::experiments::{prop1, ExpOpts};

fn main() -> laq::Result<()> {
    laq::util::logging::init();
    let iters: Option<usize> = std::env::args().nth(1).and_then(|s| s.parse().ok());
    let opts = ExpOpts {
        quick: iters.map(|i| i <= 500).unwrap_or(true),
        out_dir: "results".into(),
        ..Default::default()
    };
    let report = prop1::run(&opts)?;
    println!("{report}");
    Ok(())
}
